//! TokenPicker (Park et al., DAC'24) baseline model.
//!
//! Mechanism: no separate predictor — Keys are consumed in **4-bit chunks**
//! (three chunks for INT12), MSB-chunk first; after each chunk the design
//! estimates each token's **post-exp probability** and prunes tokens whose
//! estimated softmax weight falls below a minimum; partial chunk results are
//! reused (no re-fetch). Differences from BitStopper that the paper calls out
//! (§VI): coarser granularity (4-bit vs 1-bit — a token that dies at bit 1
//! still paid for bits 0–3), a costlier decision rule (exponentials per token
//! per round instead of a max-relative compare), and decode-only operation.
//!
//! Our model: chunk-granular interval bounds (the 4-bit analogue of the bit
//! margin), a post-exp band calibrated for target vital recall, and
//! round-synchronous progressive fetching (chunk r+1 of a token is requested
//! only after its round-r decision).

use super::{logit_scale, recall, vital_set_int, RECALL_TARGET, VITAL_MASS};
use crate::algo::complexity::Complexity;
use crate::config::SimConfig;
use crate::energy::EnergyModel;
use crate::quant::bitplane::N_BITS;
use crate::quant::IntMatrix;
use crate::sim::accelerator::SimReport;
use crate::sim::dram::{Dram, DramConfig};
use crate::sim::qkpu::{assign_round_robin, simulate_lanes, ChainTask, FetchSpec};
use crate::sim::vpu::simulate_vpu;
use crate::sim::Cycle;
use crate::workload::QuantAttn;

/// Chunk width in bits; 12-bit operands → 3 chunks.
pub const CHUNK_BITS: usize = 4;
pub const N_CHUNKS: usize = N_BITS / CHUNK_BITS;

/// Signed value contribution of chunk `c` of an INT12 value (chunk 0 holds
/// the sign nibble).
#[inline]
fn chunk_value(v: i16, c: usize) -> i32 {
    match c {
        0 => ((v >> 8) as i32) << 8, // arithmetic shift keeps the sign
        1 => (((v >> 4) & 0xF) as i32) << 4,
        _ => (v & 0xF) as i32,
    }
}

/// Positive weight remaining after chunks `0..=c`.
#[inline]
fn chunk_remaining(c: usize) -> i64 {
    match c {
        0 => 255,
        1 => 15,
        _ => 0,
    }
}

/// Per-chunk dot-product increments for one key.
fn chunk_dot(q: &[i16], k: &IntMatrix, j: usize, c: usize) -> i64 {
    k.row(j)
        .iter()
        .zip(q.iter())
        .map(|(&kv, &qv)| chunk_value(kv, c) as i64 * qv as i64)
        .sum()
}

/// Progressive chunk selection: returns per-key death chunk (N_CHUNKS =
/// survived) and the surviving set. `band` is the post-exp pruning band in
/// integer-score units: prune when `upper < max_lower − band`.
pub fn chunk_select(q: &[i16], k: &IntMatrix, band: i64) -> (Vec<u8>, Vec<usize>) {
    let seq = k.rows;
    let pos_sum: i64 = q.iter().map(|&v| (v as i64).max(0)).sum();
    let neg_sum: i64 = q.iter().map(|&v| (v as i64).min(0)).sum();
    let mut partial = vec![0i64; seq];
    let mut death = vec![N_CHUNKS as u8; seq];
    let mut active: Vec<usize> = (0..seq).collect();
    for c in 0..N_CHUNKS {
        for &j in &active {
            partial[j] += chunk_dot(q, k, j, c);
        }
        let rem = chunk_remaining(c);
        let m_max = rem * pos_sum;
        let m_min = rem * neg_sum;
        let max_lower = active.iter().map(|&j| partial[j] + m_min).max().unwrap_or(0);
        let eta = max_lower - band;
        active.retain(|&j| {
            if partial[j] + m_max >= eta {
                true
            } else {
                death[j] = c as u8;
                false
            }
        });
        if active.is_empty() {
            break;
        }
    }
    (death, active)
}

/// Calibrate the post-exp band for target vital recall.
fn calibrate_band(qa: &QuantAttn) -> i64 {
    let scale = logit_scale(qa);
    let n_cal = qa.queries.len().min(8);
    // Band in logit units swept 0.5..16; convert to integer domain.
    let mut band_logit = 0.5f64;
    while band_logit < 16.0 {
        let band = (band_logit / scale as f64) as i64;
        let mean_recall: f64 = qa
            .queries
            .iter()
            .take(n_cal)
            .map(|q| {
                let (_, surv) = chunk_select(q, &qa.k, band);
                let vital = vital_set_int(q, &qa.k, scale, VITAL_MASS);
                recall(&surv, &vital)
            })
            .sum::<f64>()
            / n_cal.max(1) as f64;
        if mean_recall >= RECALL_TARGET {
            return band;
        }
        band_logit *= 1.3;
    }
    (16.0 / scale as f64) as i64
}

/// Simulate TokenPicker on a workload.
pub fn simulate_tokenpicker(qa: &QuantAttn, cfg: &SimConfig) -> SimReport {
    let seq = qa.seq();
    let dim = qa.dim();
    let hw = &cfg.hw;
    let mut dram = Dram::new(DramConfig::hbm2_from(hw));
    let band = calibrate_band(qa);

    let chunk_row_bytes = ((dim * CHUNK_BITS).div_ceil(8)) as u64;
    let full_row_bytes = ((dim * N_BITS).div_ceil(8)) as u64;
    // 12-bit Q × 4-bit chunk.
    let chunk_compute = super::compute_cycles(dim, N_BITS, CHUNK_BITS, hw);
    let v_base = N_CHUNKS as u64 * seq as u64 * chunk_row_bytes + seq as u64 * full_row_bytes;

    let mut cx = Complexity::default();
    let mut stage_free: Cycle = 0;
    let mut vpu_free: Cycle = 0;
    let mut busy = 0u64;
    let mut span_end: Cycle = 0;
    let mut survivors_total = 0u64;
    let mut chunks_fetched = 0u64;

    for q in &qa.queries {
        let (death, survivors) = chunk_select(q, &qa.k, band);

        // Round-synchronous progressive chunks: round c fetches chunk c of all
        // still-active tokens, then a post-exp decision barrier.
        let mut t = stage_free;
        for c in 0..N_CHUNKS {
            // A key processes chunk c iff it was not pruned in an earlier
            // round (death == c means it processed chunk c and then died).
            let active: Vec<usize> = (0..seq).filter(|&j| death[j] as usize >= c).collect();
            if active.is_empty() {
                break;
            }
            let chains: Vec<ChainTask> = active
                .iter()
                .map(|&j| ChainTask {
                    steps: vec![FetchSpec {
                        addr: (c as u64 * seq as u64 + j as u64) * chunk_row_bytes,
                        bytes: chunk_row_bytes,
                        compute: chunk_compute,
                    }],
                })
                .collect();
            let r = simulate_lanes(&assign_round_robin(chains, hw.pe_lanes), &mut dram, t, 16);
            busy += r.busy_cycles;
            cx.k_bits += (active.len() * dim * CHUNK_BITS) as u64;
            cx.bit_ops += (active.len() * dim * CHUNK_BITS) as u64; // 12b×4b = 4 plane-equivalents
            // Post-exp decision: one exponential per active token per round —
            // the "significant computational overhead" of §VI.
            cx.softmax_ops += active.len() as u64;
            chunks_fetched += active.len() as u64;
            // Decision barrier: exp-unit throughput 8 tokens/cycle.
            t = r.finish + (active.len() as u64).div_ceil(8);
        }
        cx.q_bits += (dim * N_BITS) as u64;
        span_end = span_end.max(t);

        // V stage over survivors (partials reused — no K re-fetch).
        let vpu_start = t.max(vpu_free);
        let v = simulate_vpu(&survivors, dim, hw.vpu_macs, &mut dram, vpu_start, v_base);
        vpu_free = v.finish;
        cx.v_bits += v.v_bits;
        cx.mac_ops += v.mac_ops;
        cx.softmax_ops += v.softmax_ops;
        survivors_total += survivors.len() as u64;

        stage_free = t;
    }

    let emodel = EnergyModel { kv_buffer_bytes: hw.kv_buffer_bytes, ..Default::default() };
    let energy = emodel.energy(&cx, EnergyModel::default_sram_bits(&cx), chunks_fetched);
    let n_q = qa.queries.len();
    SimReport {
        queries: n_q,
        seq,
        dim,
        cycles: vpu_free.max(span_end),
        qk_busy: busy,
        qk_span: span_end,
        lanes: hw.pe_lanes,
        utilization: if span_end > 0 {
            busy as f64 / (hw.pe_lanes as f64 * span_end as f64)
        } else {
            0.0
        },
        complexity: cx,
        energy,
        dram: dram.stats,
        scoreboard: Default::default(),
        keep_rate: survivors_total as f64 / (n_q * seq).max(1) as f64,
        k_traffic_fraction: chunks_fetched as f64 * CHUNK_BITS as f64
            / (n_q as u64 * seq as u64 * N_BITS as u64).max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::accelerator::simulate_attention;

    fn workload(seq: usize, queries: usize, seed: u64) -> QuantAttn {
        QuantAttn::synth(seq, 64, queries, seed)
    }

    #[test]
    fn chunks_reconstruct_value() {
        for v in [-2048i16, -1000, -5, 0, 3, 77, 2047] {
            let sum: i32 = (0..N_CHUNKS).map(|c| chunk_value(v, c)).sum();
            assert_eq!(sum, v as i32, "value {v}");
        }
    }

    #[test]
    fn chunk_bounds_are_sound() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(31);
        let dim = 16;
        let q: Vec<i16> = (0..dim).map(|_| rng.range_i64(-2048, 2047) as i16).collect();
        let kd: Vec<i16> = (0..dim).map(|_| rng.range_i64(-2048, 2047) as i16).collect();
        let k = IntMatrix::new(1, dim, kd);
        let exact = k.dot_row(0, &q);
        let pos: i64 = q.iter().map(|&v| (v as i64).max(0)).sum();
        let neg: i64 = q.iter().map(|&v| (v as i64).min(0)).sum();
        let mut partial = 0i64;
        for c in 0..N_CHUNKS {
            partial += chunk_dot(&q, &k, 0, c);
            let rem = chunk_remaining(c);
            assert!(partial + rem * neg <= exact, "chunk {c}");
            assert!(partial + rem * pos >= exact, "chunk {c}");
        }
        assert_eq!(partial, exact);
    }

    #[test]
    fn argmax_survives_chunk_selection() {
        let qa = workload(128, 4, 32);
        for q in &qa.queries {
            let (_, surv) = chunk_select(q, &qa.k, 1);
            let exact: Vec<i64> = (0..128).map(|j| qa.k.dot_row(j, q)).collect();
            let argmax = (0..128).max_by_key(|&j| exact[j]).unwrap();
            assert!(surv.contains(&argmax));
        }
    }

    #[test]
    fn tokenpicker_between_dense_and_bitstopper_on_traffic() {
        let qa = workload(1024, 8, 33);
        let cfg = SimConfig::default();
        let tp = simulate_tokenpicker(&qa, &cfg);
        let bs = simulate_attention(&qa, &cfg);
        // 4-bit chunks cannot stop earlier than bit 4: BitStopper's 1-bit
        // granularity must win on K traffic.
        assert!(
            bs.complexity.k_bits < tp.complexity.k_bits,
            "bs {} tp {}",
            bs.complexity.k_bits,
            tp.complexity.k_bits
        );
        // But TokenPicker still beats dense 12-bit streaming.
        assert!(tp.k_traffic_fraction < 1.0);
    }
}
