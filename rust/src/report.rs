//! Report emission: fixed-width text tables (stdout + files) for the figure
//! harness — the offline substitute for a plotting stack.

use std::fmt::Write as _;

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: build a row from display items.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV rendering for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }
}

/// Format a float with fixed precision (table helper).
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Write a rendered report to `dir/name.txt` (+ `.csv`), creating the dir.
pub fn save(dir: &std::path::Path, name: &str, table: &Table) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), table.render())?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2.50".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("longer"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("demo", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(1.23456, 2), "1.23");
    }
}
