//! Tiny-transformer substrate: a GPT-style character-level model whose
//! weights are trained by `python/compile/train_tiny.py` (JAX, build time)
//! and executed here in Rust for quality experiments.
//!
//! Role in the reproduction: the paper evaluates pruning quality as perplexity
//! on OPT-1.3B / Llama2-7B. Those weights are unavailable, so the PPL-vs-α
//! trends (Fig. 10's PPL column, Fig. 13 (a)) are measured on this model —
//! a real trained LM with real attention distributions — with the *same*
//! selection policies the accelerator implements (see DESIGN.md §2).
//!
//! Architecture (pre-LN GPT): token + positional embeddings, `n_layers` ×
//! [LN → causal MHA → residual, LN → GELU MLP → residual], final LN, tied or
//! untied LM head.

pub mod loader;
pub mod ppl;

pub use loader::{load_weights, TinyConfig, Weights};
pub use ppl::{evaluate_ppl, AttnPolicy, PplReport};

use crate::attention::softmax_inplace;

/// The model with its weights resident.
#[derive(Debug)]
pub struct TinyTransformer {
    pub cfg: TinyConfig,
    pub w: Weights,
}

/// Row-major matmul: `out[m×n] = x[m×k] · w[k×n]`.
fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let xv = x[i * k + p];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// LayerNorm over the last dim.
fn layer_norm(x: &mut [f32], g: &[f32], b: &[f32], d: usize) {
    for row in x.chunks_exact_mut(d) {
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * g[i] + b[i];
        }
    }
}

/// tanh-approximation GELU (matches the JAX trainer).
#[inline]
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

impl TinyTransformer {
    pub fn new(cfg: TinyConfig, w: Weights) -> Self {
        Self { cfg, w }
    }

    /// Full forward pass over a token window; returns logits `[seq × vocab]`.
    ///
    /// `policy` controls which keys each attention query may attend to —
    /// `AttnPolicy::Dense` reproduces the training-time model; the pruning
    /// policies reproduce the accelerator's selection.
    pub fn forward(&self, tokens: &[u16], policy: &AttnPolicy) -> Vec<f32> {
        self.forward_with_stats(tokens, policy).0
    }

    /// Forward pass that also reports attention pruning statistics:
    /// `(logits, kept_keys, total_keys)` summed over layers/heads/positions.
    pub fn forward_with_stats(
        &self,
        tokens: &[u16],
        policy: &AttnPolicy,
    ) -> (Vec<f32>, u64, u64) {
        let cfg = &self.cfg;
        let s = tokens.len();
        assert!(s <= cfg.max_seq, "window {} exceeds max_seq {}", s, cfg.max_seq);
        let d = cfg.d_model;
        let heads = cfg.n_heads;
        let hd = d / heads;

        // Embeddings.
        let mut x = vec![0f32; s * d];
        for (i, &t) in tokens.iter().enumerate() {
            let te = &self.w.tok_emb[t as usize * d..(t as usize + 1) * d];
            let pe = &self.w.pos_emb[i * d..(i + 1) * d];
            for c in 0..d {
                x[i * d + c] = te[c] + pe[c];
            }
        }

        let mut kept_keys = 0u64;
        let mut total_keys = 0u64;
        let mut q = vec![0f32; s * d];
        let mut k = vec![0f32; s * d];
        let mut v = vec![0f32; s * d];
        let mut attn_out = vec![0f32; s * d];
        let mut proj = vec![0f32; s * d];
        let mut h1 = vec![0f32; s * 4 * d];
        let mut h2 = vec![0f32; s * d];

        for layer in &self.w.layers {
            // --- attention block ---
            let mut xin = x.clone();
            layer_norm(&mut xin, &layer.ln1_g, &layer.ln1_b, d);
            matmul(&xin, &layer.wq, s, d, d, &mut q);
            matmul(&xin, &layer.wk, s, d, d, &mut k);
            matmul(&xin, &layer.wv, s, d, d, &mut v);

            attn_out.fill(0.0);
            let scale = 1.0 / (hd as f32).sqrt();
            for h in 0..heads {
                let off = h * hd;
                for i in 0..s {
                    // Causal context 0..=i.
                    let qi = &q[i * d + off..i * d + off + hd];
                    let mut logits: Vec<f32> = (0..=i)
                        .map(|j| {
                            let kj = &k[j * d + off..j * d + off + hd];
                            qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale
                        })
                        .collect();
                    let keep = policy.select(&logits);
                    total_keys += (i + 1) as u64;
                    match keep {
                        Some(idx) => {
                            kept_keys += idx.len() as u64;
                            // Sparse softmax over survivors only.
                            let mut sub: Vec<f32> = idx.iter().map(|&j| logits[j]).collect();
                            softmax_inplace(&mut sub);
                            for (w_attn, &j) in sub.iter().zip(&idx) {
                                let vj = &v[j * d + off..j * d + off + hd];
                                let out = &mut attn_out[i * d + off..i * d + off + hd];
                                for (o, &vv) in out.iter_mut().zip(vj) {
                                    *o += w_attn * vv;
                                }
                            }
                        }
                        None => {
                            kept_keys += (i + 1) as u64;
                            softmax_inplace(&mut logits);
                            for (j, &w_attn) in logits.iter().enumerate() {
                                let vj = &v[j * d + off..j * d + off + hd];
                                let out = &mut attn_out[i * d + off..i * d + off + hd];
                                for (o, &vv) in out.iter_mut().zip(vj) {
                                    *o += w_attn * vv;
                                }
                            }
                        }
                    }
                }
            }
            matmul(&attn_out, &layer.wo, s, d, d, &mut proj);
            for (xv, &p) in x.iter_mut().zip(proj.iter()) {
                *xv += p;
            }

            // --- MLP block ---
            let mut xin2 = x.clone();
            layer_norm(&mut xin2, &layer.ln2_g, &layer.ln2_b, d);
            matmul(&xin2, &layer.w1, s, d, 4 * d, &mut h1);
            for (i, hv) in h1.iter_mut().enumerate() {
                *hv = gelu(*hv + layer.b1[i % (4 * d)]);
            }
            matmul(&h1, &layer.w2, s, 4 * d, d, &mut h2);
            for (i, xv) in x.iter_mut().enumerate() {
                *xv += h2[i] + layer.b2[i % d];
            }
        }

        layer_norm(&mut x, &self.w.lnf_g, &self.w.lnf_b, d);
        let mut logits = vec![0f32; s * cfg.vocab];
        matmul(&x, &self.w.lm_head, s, d, cfg.vocab, &mut logits);
        (logits, kept_keys, total_keys)
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::loader::{LayerWeights, TinyConfig, Weights};
    use crate::util::SplitMix64;

    /// A small random-weight model for unit tests (scaled for stable norms).
    pub fn random_model(seed: u64) -> super::TinyTransformer {
        let cfg = TinyConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 24 };
        let mut rng = SplitMix64::new(seed);
        let d = cfg.d_model;
        let mut t = |n: usize, scale: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: t(d * d, 0.15),
                wk: t(d * d, 0.15),
                wv: t(d * d, 0.15),
                wo: t(d * d, 0.15),
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                w1: t(d * 4 * d, 0.15),
                b1: vec![0.0; 4 * d],
                w2: t(4 * d * d, 0.15),
                b2: vec![0.0; d],
            })
            .collect();
        let w = Weights {
            tok_emb: t(cfg.vocab * d, 0.3),
            pos_emb: t(cfg.max_seq * d, 0.1),
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            lm_head: t(d * cfg.vocab, 0.2),
        };
        super::TinyTransformer::new(cfg, w)
    }
}

#[cfg(test)]
mod tests {
    use super::ppl::AttnPolicy;
    use super::test_support::random_model;

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = random_model(1);
        let tokens: Vec<u16> = (0..10).map(|i| (i % 32) as u16).collect();
        let logits = m.forward(&tokens, &AttnPolicy::Dense);
        assert_eq!(logits.len(), 10 * 32);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn dense_and_full_lats_agree() {
        // With a huge band, LATS keeps every key → outputs must match dense.
        let m = random_model(2);
        let tokens: Vec<u16> = (0..12).map(|i| ((i * 7) % 32) as u16).collect();
        let dense = m.forward(&tokens, &AttnPolicy::Dense);
        let lats = m.forward(&tokens, &AttnPolicy::Lats { alpha: 1.0, radius: 1e9 });
        for (a, b) in dense.iter().zip(&lats) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn aggressive_pruning_changes_but_does_not_break_output() {
        let m = random_model(3);
        let tokens: Vec<u16> = (0..16).map(|i| ((i * 3) % 32) as u16).collect();
        let pruned = m.forward(&tokens, &AttnPolicy::Lats { alpha: 0.1, radius: 1.0 });
        assert!(pruned.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality_prefix_logits_stable() {
        // Logits at position i must not depend on tokens after i.
        let m = random_model(4);
        let t1: Vec<u16> = vec![1, 2, 3, 4, 5, 6];
        let t2: Vec<u16> = vec![1, 2, 3, 4, 31, 30];
        let l1 = m.forward(&t1, &AttnPolicy::Dense);
        let l2 = m.forward(&t2, &AttnPolicy::Dense);
        let vocab = 32;
        for c in 0..vocab {
            for i in 0..4 {
                assert!(
                    (l1[i * vocab + c] - l2[i * vocab + c]).abs() < 1e-5,
                    "position {i} leaked future tokens"
                );
            }
        }
    }
}
