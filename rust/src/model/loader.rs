//! Weight / token-stream binary formats shared with `python/compile/train_tiny.py`.
//!
//! Weights (`weights.bin`, little-endian):
//! ```text
//! magic "BSWGHT01"
//! u32 vocab, d_model, n_layers, n_heads, max_seq
//! u32 n_tensors
//! repeat: u16 name_len, name (utf8), u32 ndim, u32 dims[ndim], f32 data[]
//! ```
//!
//! Token streams (`val_tokens.bin`): magic `"BSTOK001"`, `u32 n`, `u16 tokens[n]`.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

pub const WEIGHTS_MAGIC: &[u8; 8] = b"BSWGHT01";
pub const TOKENS_MAGIC: &[u8; 8] = b"BSTOK001";

/// Model hyperparameters (from the weights header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TinyConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
}

/// One decoder layer's parameters.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// All model parameters.
#[derive(Debug, Clone)]
pub struct Weights {
    pub tok_emb: Vec<f32>,
    pub pos_emb: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub lm_head: Vec<f32>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Parse a weights file into a config + named-tensor map, then assemble.
pub fn load_weights(path: &Path) -> Result<(TinyConfig, Weights)> {
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != WEIGHTS_MAGIC {
        bail!("bad weights magic");
    }
    let cfg = TinyConfig {
        vocab: read_u32(&mut f)? as usize,
        d_model: read_u32(&mut f)? as usize,
        n_layers: read_u32(&mut f)? as usize,
        n_heads: read_u32(&mut f)? as usize,
        max_seq: read_u32(&mut f)? as usize,
    };
    if cfg.vocab == 0 || cfg.d_model == 0 || cfg.n_layers == 0 || cfg.n_heads == 0 {
        bail!("degenerate config {cfg:?}");
    }
    if cfg.d_model % cfg.n_heads != 0 {
        bail!("d_model {} not divisible by heads {}", cfg.d_model, cfg.n_heads);
    }
    let n_tensors = read_u32(&mut f)? as usize;
    let mut tensors: HashMap<String, Vec<f32>> = HashMap::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let name_len = read_u16(&mut f)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        f.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name utf8")?;
        let ndim = read_u32(&mut f)? as usize;
        if ndim > 4 {
            bail!("tensor {name}: implausible ndim {ndim}");
        }
        let mut numel = 1usize;
        for _ in 0..ndim {
            numel = numel.saturating_mul(read_u32(&mut f)? as usize);
        }
        if numel > 256 << 20 {
            bail!("tensor {name}: implausible size {numel}");
        }
        tensors.insert(name, read_f32s(&mut f, numel)?);
    }

    let mut take = |name: String, expect: usize| -> Result<Vec<f32>> {
        let t = tensors
            .remove(&name)
            .with_context(|| format!("missing tensor {name}"))?;
        if t.len() != expect {
            bail!("tensor {name}: expected {expect} elements, got {}", t.len());
        }
        Ok(t)
    };

    let d = cfg.d_model;
    let layers = (0..cfg.n_layers)
        .map(|i| -> Result<LayerWeights> {
            let p = |s: &str| format!("layers.{i}.{s}");
            Ok(LayerWeights {
                ln1_g: take(p("ln1.g"), d)?,
                ln1_b: take(p("ln1.b"), d)?,
                wq: take(p("wq"), d * d)?,
                wk: take(p("wk"), d * d)?,
                wv: take(p("wv"), d * d)?,
                wo: take(p("wo"), d * d)?,
                ln2_g: take(p("ln2.g"), d)?,
                ln2_b: take(p("ln2.b"), d)?,
                w1: take(p("w1"), d * 4 * d)?,
                b1: take(p("b1"), 4 * d)?,
                w2: take(p("w2"), 4 * d * d)?,
                b2: take(p("b2"), d)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let w = Weights {
        tok_emb: take("tok_emb".into(), cfg.vocab * d)?,
        pos_emb: take("pos_emb".into(), cfg.max_seq * d)?,
        layers,
        lnf_g: take("ln_f.g".into(), d)?,
        lnf_b: take("ln_f.b".into(), d)?,
        lm_head: take("lm_head".into(), d * cfg.vocab)?,
    };
    Ok((cfg, w))
}

/// Load a token stream file.
pub fn load_tokens(path: &Path) -> Result<Vec<u16>> {
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != TOKENS_MAGIC {
        bail!("bad tokens magic");
    }
    let n = read_u32(&mut f)? as usize;
    let mut bytes = vec![0u8; n * 2];
    f.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
}

/// Test/fixture writer (the production writer is `train_tiny.py`).
pub fn write_weights(path: &Path, cfg: &TinyConfig, w: &Weights) -> Result<()> {
    use std::io::Write;
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(WEIGHTS_MAGIC);
    for v in [cfg.vocab, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.max_seq] {
        buf.extend_from_slice(&(v as u32).to_le_bytes());
    }
    let mut tensors: Vec<(String, Vec<usize>, &[f32])> = vec![
        ("tok_emb".into(), vec![cfg.vocab, cfg.d_model], &w.tok_emb),
        ("pos_emb".into(), vec![cfg.max_seq, cfg.d_model], &w.pos_emb),
    ];
    for (i, l) in w.layers.iter().enumerate() {
        let d = cfg.d_model;
        let p = |s: &str| format!("layers.{i}.{s}");
        tensors.push((p("ln1.g"), vec![d], &l.ln1_g));
        tensors.push((p("ln1.b"), vec![d], &l.ln1_b));
        tensors.push((p("wq"), vec![d, d], &l.wq));
        tensors.push((p("wk"), vec![d, d], &l.wk));
        tensors.push((p("wv"), vec![d, d], &l.wv));
        tensors.push((p("wo"), vec![d, d], &l.wo));
        tensors.push((p("ln2.g"), vec![d], &l.ln2_g));
        tensors.push((p("ln2.b"), vec![d], &l.ln2_b));
        tensors.push((p("w1"), vec![d, 4 * d], &l.w1));
        tensors.push((p("b1"), vec![4 * d], &l.b1));
        tensors.push((p("w2"), vec![4 * d, d], &l.w2));
        tensors.push((p("b2"), vec![d], &l.b2));
    }
    tensors.push(("ln_f.g".into(), vec![cfg.d_model], &w.lnf_g));
    tensors.push(("ln_f.b".into(), vec![cfg.d_model], &w.lnf_b));
    tensors.push(("lm_head".into(), vec![cfg.d_model, cfg.vocab], &w.lm_head));

    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, dims, data) in tensors {
        buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in &dims {
            buf.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name}");
        for &x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::File::create(path)?.write_all(&buf)?;
    Ok(())
}

/// Test/fixture writer for token streams.
pub fn write_tokens(path: &Path, tokens: &[u16]) -> Result<()> {
    use std::io::Write;
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(TOKENS_MAGIC);
    buf.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    for &t in tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    std::fs::File::create(path)?.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::random_model;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("bitstopper_model_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn weights_roundtrip() {
        let m = random_model(10);
        let p = tmp("w_roundtrip");
        write_weights(&p, &m.cfg, &m.w).unwrap();
        let (cfg, w) = load_weights(&p).unwrap();
        assert_eq!(cfg, m.cfg);
        assert_eq!(w.tok_emb, m.w.tok_emb);
        assert_eq!(w.layers.len(), m.w.layers.len());
        assert_eq!(w.layers[1].w2, m.w.layers[1].w2);
        assert_eq!(w.lm_head, m.w.lm_head);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn tokens_roundtrip() {
        let p = tmp("t_roundtrip");
        let toks: Vec<u16> = (0..1000).map(|i| (i % 97) as u16).collect();
        write_tokens(&p, &toks).unwrap();
        assert_eq!(load_tokens(&p).unwrap(), toks);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupted_magic_rejected() {
        let p = tmp("bad");
        std::fs::write(&p, b"GARBAGE!").unwrap();
        assert!(load_weights(&p).is_err());
        assert!(load_tokens(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_tensor_rejected() {
        // Write a header claiming 0 tensors: loader must fail on take().
        let p = tmp("missing");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(WEIGHTS_MAGIC);
        for v in [32u32, 16, 1, 2, 8] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&p, &buf).unwrap();
        assert!(load_weights(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
