//! Perplexity evaluation with pluggable attention-pruning policies — the
//! quality axis of Fig. 10 and Fig. 13 (a).
//!
//! A policy decides, per attention query, which causal keys survive; the
//! model then computes an exact sparse softmax over the survivors. PPL is
//! measured by sliding a non-overlapping window over a held-out token stream
//! and averaging token NLL.

use super::TinyTransformer;
use crate::algo::selection::{lats_select_logits, static_threshold_select, topk_select};

/// Attention selection policy used during evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttnPolicy {
    /// Full attention (the INT12 accuracy baseline of §V-A).
    Dense,
    /// BitStopper's LATS rule: keep logits within `alpha × radius` of the max.
    Lats { alpha: f64, radius: f64 },
    /// Sanger-style absolute static threshold in the logit domain.
    StaticThreshold { theta: f32 },
    /// SOFA-style fixed top-k.
    TopK { k: usize },
}

impl AttnPolicy {
    /// Returns surviving key indices for a query's logits, or `None` for
    /// dense (keep everything, skip the indirection).
    pub fn select(&self, logits: &[f32]) -> Option<Vec<usize>> {
        match *self {
            AttnPolicy::Dense => None,
            AttnPolicy::Lats { alpha, radius } => {
                Some(lats_select_logits(logits, alpha, radius))
            }
            AttnPolicy::StaticThreshold { theta } => {
                let sel = static_threshold_select(logits, theta);
                // Never return an empty context: hardware always keeps the max.
                if sel.is_empty() {
                    Some(vec![argmax(logits)])
                } else {
                    Some(sel)
                }
            }
            AttnPolicy::TopK { k } => Some(topk_select(logits, k.max(1))),
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// PPL evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct PplReport {
    pub ppl: f64,
    pub nll: f64,
    pub tokens: usize,
}

/// Evaluate perplexity of `model` on `tokens` under `policy`, using
/// non-overlapping windows of `window` tokens (the standard strided protocol
/// with stride = window).
pub fn evaluate_ppl(
    model: &TinyTransformer,
    tokens: &[u16],
    window: usize,
    policy: &AttnPolicy,
) -> PplReport {
    assert!(window >= 2);
    let vocab = model.cfg.vocab;
    let mut total_nll = 0f64;
    let mut count = 0usize;

    let mut start = 0usize;
    while start + 2 <= tokens.len() {
        let end = (start + window).min(tokens.len());
        let ctx = &tokens[start..end];
        if ctx.len() < 2 {
            break;
        }
        let logits = model.forward(ctx, policy);
        // Predict token i+1 from position i.
        for i in 0..ctx.len() - 1 {
            let row = &logits[i * vocab..(i + 1) * vocab];
            let target = ctx[i + 1] as usize;
            // log-softmax.
            let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
            total_nll += (lse - row[target]) as f64;
            count += 1;
        }
        start = end;
    }

    let nll = if count == 0 { 0.0 } else { total_nll / count as f64 };
    PplReport { ppl: nll.exp(), nll, tokens: count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_support::random_model;
    use crate::util::SplitMix64;

    fn tokens(n: usize, vocab: u16, seed: u64) -> Vec<u16> {
        let mut rng = SplitMix64::new(seed);
        (0..n).map(|_| rng.below(vocab as u64) as u16).collect()
    }

    #[test]
    fn ppl_of_random_model_near_uniform() {
        // An untrained model on random tokens ≈ uniform prediction: PPL ≈ vocab.
        let m = random_model(20);
        let toks = tokens(200, 32, 21);
        let r = evaluate_ppl(&m, &toks, 24, &AttnPolicy::Dense);
        assert!(r.ppl > 16.0 && r.ppl < 70.0, "ppl {}", r.ppl);
        // Windows lose 1 token each.
        assert_eq!(r.tokens, 200 - 200usize.div_ceil(24).max(200 / 24));
    }

    #[test]
    fn permissive_lats_matches_dense_ppl() {
        let m = random_model(22);
        let toks = tokens(120, 32, 23);
        let dense = evaluate_ppl(&m, &toks, 24, &AttnPolicy::Dense);
        let lats = evaluate_ppl(&m, &toks, 24, &AttnPolicy::Lats { alpha: 1.0, radius: 1e9 });
        assert!((dense.ppl - lats.ppl).abs() / dense.ppl < 1e-4);
    }

    #[test]
    fn harsher_pruning_degrades_ppl_monotonically_in_expectation() {
        let m = random_model(24);
        let toks = tokens(200, 32, 25);
        let full = evaluate_ppl(&m, &toks, 24, &AttnPolicy::Dense).ppl;
        let mild = evaluate_ppl(&m, &toks, 24, &AttnPolicy::Lats { alpha: 0.8, radius: 5.0 }).ppl;
        let harsh = evaluate_ppl(&m, &toks, 24, &AttnPolicy::TopK { k: 1 }).ppl;
        // top-1 attention is a big distortion; it should hurt more than a wide
        // LATS band (relative to dense).
        let d_mild = (mild - full).abs();
        let d_harsh = (harsh - full).abs();
        assert!(d_harsh >= d_mild, "harsh {d_harsh} vs mild {d_mild}");
    }

    #[test]
    fn policy_select_never_empty() {
        let logits = vec![-5.0f32, -9.0, -7.0];
        for p in [
            AttnPolicy::Lats { alpha: 0.1, radius: 0.1 },
            AttnPolicy::StaticThreshold { theta: 100.0 },
            AttnPolicy::TopK { k: 1 },
        ] {
            let sel = p.select(&logits).unwrap();
            assert!(!sel.is_empty(), "{p:?}");
            assert!(sel.contains(&0), "{p:?} must keep the max");
        }
    }

    #[test]
    fn empty_token_stream_is_safe() {
        let m = random_model(26);
        let r = evaluate_ppl(&m, &[], 8, &AttnPolicy::Dense);
        assert_eq!(r.tokens, 0);
        assert_eq!(r.ppl, 1.0);
    }
}
