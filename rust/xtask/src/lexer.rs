//! A tiny Rust source "code view" lexer for the lint pass.
//!
//! The offline build has no `syn`, so the rules in [`crate::rules`] work on
//! three views of each file produced here:
//!
//! * **code** — the source with comments and string/char-literal bodies
//!   blanked to spaces, every newline preserved, so any position keeps its
//!   original 1-indexed line. Pattern matches against this view can never
//!   fire inside a comment or a string.
//! * **comments** — comment text per line (the `lint:allow` suppression
//!   channel).
//! * **strings** — every string-literal body with its start line (the L6
//!   bench-name channel).
//!
//! The lexer only has to be exact about *boundaries*: line and nested block
//! comments, plain/byte strings with escapes, raw strings (`r"…"`,
//! `r#"…"#`, `br"…"`), and char literals vs lifetimes (`'a'` vs `'a`).

use std::collections::HashMap;

/// Lexed views of one source file (see module docs).
pub struct Lexed {
    pub code: String,
    pub comments: HashMap<usize, String>,
    pub strings: Vec<(usize, String)>,
}

/// Produce the lexed views of `src`.
pub fn lex(src: &str) -> Lexed {
    Lexer {
        cs: src.chars().collect(),
        i: 0,
        line: 1,
        code: String::with_capacity(src.len()),
        comments: HashMap::new(),
        strings: Vec::new(),
    }
    .run()
}

struct Lexer {
    cs: Vec<char>,
    i: usize,
    line: usize,
    code: String,
    comments: HashMap<usize, String>,
    strings: Vec<(usize, String)>,
}

impl Lexer {
    fn at(&self, k: usize) -> Option<char> {
        self.cs.get(self.i + k).copied()
    }

    /// Consume one char, blanking it in the code view (newlines pass
    /// through so line numbers survive).
    fn blank(&mut self) {
        if self.cs[self.i] == '\n' {
            self.code.push('\n');
            self.line += 1;
        } else {
            self.code.push(' ');
        }
        self.i += 1;
    }

    /// Consume one char, keeping it in the code view.
    fn keep(&mut self) {
        let c = self.cs[self.i];
        if c == '\n' {
            self.line += 1;
        }
        self.code.push(c);
        self.i += 1;
    }

    fn note_comment(&mut self, line: usize, text: &str) {
        let slot = self.comments.entry(line).or_default();
        if !slot.is_empty() {
            slot.push(' ');
        }
        slot.push_str(text);
    }

    fn run(mut self) -> Lexed {
        let mut prev_ident = false;
        while self.i < self.cs.len() {
            let c = self.cs[self.i];
            if c == '/' && self.at(1) == Some('/') {
                self.line_comment();
                prev_ident = false;
            } else if c == '/' && self.at(1) == Some('*') {
                self.block_comment();
                prev_ident = false;
            } else if c == '"' {
                self.string_lit();
                prev_ident = false;
            } else if !prev_ident && (c == 'r' || c == 'b') && self.try_prefixed_literal() {
                prev_ident = false;
            } else if c == '\'' {
                // `'x'` / `'\n'` are char literals; `'a` is a lifetime tick
                // whose name then flows through as ordinary code.
                let escaped = self.at(1) == Some('\\');
                if escaped || (self.at(2) == Some('\'') && self.at(1) != Some('\'')) {
                    self.char_lit();
                } else {
                    self.keep();
                }
                prev_ident = false;
            } else {
                self.keep();
                prev_ident = c.is_alphanumeric() || c == '_';
            }
        }
        Lexed { code: self.code, comments: self.comments, strings: self.strings }
    }

    /// Handle `r"…"`, `r#"…"#`, `br"…"`, `b"…"`, `b'…'` at the cursor.
    /// Returns true if a literal was consumed.
    fn try_prefixed_literal(&mut self) -> bool {
        let c = self.cs[self.i];
        let raw_from = if c == 'b' && self.at(1) == Some('r') { 2 } else { 1 };
        if c == 'r' || raw_from == 2 {
            let mut hashes = 0usize;
            while self.at(raw_from + hashes) == Some('#') {
                hashes += 1;
            }
            if self.at(raw_from + hashes) == Some('"') {
                self.raw_string(raw_from + hashes + 1, hashes);
                return true;
            }
        }
        if c == 'b' && self.at(1) == Some('"') {
            self.blank(); // the `b`
            self.string_lit();
            return true;
        }
        if c == 'b' && self.at(1) == Some('\'') {
            self.blank(); // the `b`
            self.char_lit();
            return true;
        }
        false
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        let mut j = self.i + 2;
        while j < self.cs.len() && self.cs[j] != '\n' {
            text.push(self.cs[j]);
            j += 1;
        }
        while self.i < j {
            self.blank();
        }
        self.note_comment(line, &text);
    }

    fn block_comment(&mut self) {
        let mut depth = 0usize;
        let mut text = String::new();
        while self.i < self.cs.len() {
            if self.cs[self.i] == '/' && self.at(1) == Some('*') {
                depth += 1;
                self.blank();
                self.blank();
            } else if self.cs[self.i] == '*' && self.at(1) == Some('/') {
                depth -= 1;
                self.blank();
                self.blank();
                if depth == 0 {
                    break;
                }
            } else if self.cs[self.i] == '\n' {
                let line = self.line;
                self.note_comment(line, &text);
                text.clear();
                self.blank();
            } else {
                text.push(self.cs[self.i]);
                self.blank();
            }
        }
        self.note_comment(self.line, &text);
    }

    /// Consume a `"…"` string (cursor on the opening quote).
    fn string_lit(&mut self) {
        let start = self.line;
        self.blank(); // opening quote
        let mut body = String::new();
        while self.i < self.cs.len() {
            let c = self.cs[self.i];
            if c == '\\' && self.i + 1 < self.cs.len() {
                body.push(c);
                body.push(self.cs[self.i + 1]);
                self.blank();
                self.blank();
            } else if c == '"' {
                self.blank();
                break;
            } else {
                body.push(c);
                self.blank();
            }
        }
        self.strings.push((start, body));
    }

    /// Consume a raw string; `lead` chars of prefix (through the opening
    /// quote) precede the body, which ends at `"` followed by `hashes` `#`s.
    fn raw_string(&mut self, lead: usize, hashes: usize) {
        let start = self.line;
        for _ in 0..lead {
            self.blank();
        }
        let mut body = String::new();
        while self.i < self.cs.len() {
            if self.cs[self.i] == '"' && (1..=hashes).all(|h| self.at(h) == Some('#')) {
                for _ in 0..=hashes {
                    self.blank();
                }
                break;
            }
            body.push(self.cs[self.i]);
            self.blank();
        }
        self.strings.push((start, body));
    }

    /// Consume a `'…'` char literal (cursor on the opening quote).
    fn char_lit(&mut self) {
        self.blank(); // opening quote
        while self.i < self.cs.len() {
            let c = self.cs[self.i];
            if c == '\\' && self.i + 1 < self.cs.len() {
                self.blank();
                self.blank();
            } else if c == '\'' {
                self.blank();
                break;
            } else {
                self.blank();
            }
        }
    }
}

/// Whitespace-stripped code with a per-char line map, so multi-line method
/// chains (`.partial_cmp(x)\n    .unwrap()`) match as a single pattern.
pub struct Compact {
    pub chars: Vec<char>,
    pub lines: Vec<usize>,
}

impl Compact {
    pub fn of(code: &str) -> Compact {
        let mut chars = Vec::new();
        let mut lines = Vec::new();
        let mut line = 1usize;
        for c in code.chars() {
            if c == '\n' {
                line += 1;
            } else if !c.is_whitespace() {
                chars.push(c);
                lines.push(line);
            }
        }
        Compact { chars, lines }
    }

    /// First occurrence of `pat` at or after char index `start`.
    pub fn find_from(&self, pat: &str, start: usize) -> Option<usize> {
        let p: Vec<char> = pat.chars().collect();
        if p.is_empty() || self.chars.len() < p.len() {
            return None;
        }
        (start..=self.chars.len() - p.len()).find(|&i| self.chars[i..i + p.len()] == p[..])
    }

    pub fn starts_with_at(&self, pat: &str, i: usize) -> bool {
        let p: Vec<char> = pat.chars().collect();
        i + p.len() <= self.chars.len() && self.chars[i..i + p.len()] == p[..]
    }

    /// 1-indexed source line of char index `i`.
    pub fn line_at(&self, i: usize) -> usize {
        self.lines.get(i).copied().unwrap_or(1)
    }

    /// Index just past the `)` matching the first `(` at or after `open`.
    pub fn skip_parens(&self, open: usize) -> Option<usize> {
        let mut depth = 0i32;
        for (k, &c) in self.chars.iter().enumerate().skip(open) {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(k + 1);
                    }
                }
                _ => {}
            }
        }
        None
    }
}

/// 1-indexed line ranges of `#[cfg(test)]`-gated items (attribute line →
/// closing brace line), found by brace matching on the compact view.
pub fn cfg_test_ranges(c: &Compact) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while let Some(i) = c.find_from("#[cfg(test)]", pos) {
        let Some(open) = (i..c.chars.len()).find(|&k| c.chars[k] == '{') else {
            break;
        };
        let mut depth = 0i32;
        let mut end = open;
        for (k, &ch) in c.chars.iter().enumerate().skip(open) {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = k;
                        break;
                    }
                }
                _ => {}
            }
        }
        out.push((c.line_at(i), c.line_at(end)));
        pos = end.max(i + 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked_but_lines_survive() {
        let src = "let a = 1; // .lock().unwrap()\nlet b = \".unwrap()\";\nlet c = 2;\n";
        let l = lex(src);
        assert!(!l.code.contains("unwrap"));
        assert_eq!(l.code.lines().count(), src.lines().count());
        assert_eq!(l.comments.get(&1).map(String::as_str), Some(" .lock().unwrap()"));
        assert_eq!(l.strings, vec![(2, ".unwrap()".to_string())]);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let src = "a /* x /* y */ z */ b\n";
        let l = lex(src);
        assert_eq!(l.code.trim(), "a                   b".trim());
        assert!(l.code.contains('a') && l.code.contains('b'));
        assert!(!l.code.contains('y'));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let src = "let s = r#\"quote \" inside\"#; let t = r\"plain\";\n";
        let l = lex(src);
        assert_eq!(l.strings[0].1, "quote \" inside");
        assert_eq!(l.strings[1].1, "plain");
        assert!(!l.code.contains("inside"));
    }

    #[test]
    fn lifetimes_are_code_but_char_literals_are_not() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\n";
        let l = lex(src);
        assert!(l.code.contains("<'a>"));
        assert!(l.code.contains("&'a str"));
        assert!(!l.code.contains("'x'"));
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let src = "let s = \"a\\\"b\"; let t = 1;\n";
        let l = lex(src);
        assert_eq!(l.strings[0].1, "a\\\"b");
        assert!(l.code.contains("let t = 1;"));
    }

    #[test]
    fn compact_maps_multiline_chains_to_their_first_line() {
        let c = Compact::of("x\n    .lock()\n    .unwrap();\n");
        let i = c.find_from(".lock().unwrap()", 0).expect("found");
        assert_eq!(c.line_at(i), 2);
    }

    #[test]
    fn cfg_test_ranges_cover_the_module() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn b() {}\n";
        let l = lex(src);
        let c = Compact::of(&l.code);
        assert_eq!(cfg_test_ranges(&c), vec![(2, 5)]);
    }
}
