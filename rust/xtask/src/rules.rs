//! The lint rules (L1–L8) and the suppression protocol.
//!
//! Each rule freezes one repo invariant the serving stack's safety rests on
//! (motivations and §-citations live in DESIGN.md §13). Findings carry
//! `file:line`; a finding is suppressed by a comment
//!
//! ```text
//! // lint:allow(L2): <justification>
//! ```
//!
//! on the flagged line or the line directly above it. The justification is
//! mandatory — an allow without one is itself a finding (L0) and suppresses
//! nothing.

use crate::json::{self, Value};
use crate::lexer::{cfg_test_ranges, lex, Compact, Lexed};
use std::collections::HashMap;

/// One lint finding, pointing at `path:line`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// Everything the lint pass reads, decoupled from the filesystem so the
/// rule tests can inject fixture trees.
pub struct LintInput {
    /// `.rs` files under `rust/src` as (repo-relative path, text).
    pub sources: Vec<(String, String)>,
    /// Text of `rust/benches/hotpath.rs`, if present (L6).
    pub bench: Option<String>,
    /// Baseline JSONs as (repo-relative path, text) (L6).
    pub baselines: Vec<(String, String)>,
}

/// Run every rule over the input; findings sorted by (path, line, rule).
pub fn run(input: &LintInput) -> Vec<Finding> {
    let mut out = Vec::new();
    for (rel, text) in &input.sources {
        let f = SourceView::new(rel, text);
        f.l0_bad_suppressions(&mut out);
        f.l1_lock_unwrap(&mut out);
        f.l2_partial_cmp_unwrap(&mut out);
        f.l3_scheduler_wall_clock(&mut out);
        f.l4_bare_thread_spawn(&mut out);
        f.l5_serve_error_surface(&mut out);
        f.l7_file_io_confinement(&mut out);
        f.l8_loadgen_determinism(&mut out);
    }
    l6_bench_baseline_sync(input.bench.as_deref(), &input.baselines, &input.sources, &mut out);
    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    out
}

/// Files allowed to call bare `thread::spawn` — the modules that *own*
/// worker pools and their joins. Everything else uses scoped threads.
const L4_SPAWN_ALLOWED: &[&str] = &["coordinator/mod.rs", "engine/mod.rs", "engine/model.rs"];

/// The coordinator files whose fallible `pub fn`s must speak `ServeError`.
const L5_SERVE_SURFACE: &[&str] =
    &["coordinator/api.rs", "coordinator/client.rs", "coordinator/session.rs"];

/// Files allowed direct file I/O (`std::fs` / `File` / `OpenOptions`): the
/// spill tier is the serving stack's one disk surface (DESIGN.md §14); the
/// rest are the pre-existing artifact/config loaders, report/trace writers,
/// and the CLI. New disk state goes through one of these, not a fresh
/// `std::fs` call site.
const L7_FILE_IO_ALLOWED: &[&str] = &[
    "coordinator/spill.rs",
    "model/loader.rs",
    "runtime/mod.rs",
    "main.rs",
    "report.rs",
    "workload/trace.rs",
];

struct SourceView {
    rel: String,
    lexed: Lexed,
    compact: Compact,
    tests: Vec<(usize, usize)>,
    /// line → rules allowed (with justification) on that line.
    allows: HashMap<usize, Vec<String>>,
    /// (line, rule) of allows whose justification is missing or empty.
    bad_allows: Vec<(usize, String)>,
    /// (line, name) of `fn` declarations, for enclosing-function checks.
    fns: Vec<(usize, String)>,
}

impl SourceView {
    fn new(rel: &str, text: &str) -> SourceView {
        let lexed = lex(text);
        let compact = Compact::of(&lexed.code);
        let tests = cfg_test_ranges(&compact);
        let (allows, bad_allows) = parse_allows(&lexed.comments);
        let fns = fn_decls(&lexed.code);
        SourceView { rel: rel.to_string(), lexed, compact, tests, allows, bad_allows, fns }
    }

    fn in_tests(&self, line: usize) -> bool {
        self.tests.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// A finding at `line` is suppressed by a justified allow on that line
    /// or on the line directly above it.
    fn allowed(&self, line: usize, rule: &str) -> bool {
        [line, line.saturating_sub(1)]
            .iter()
            .any(|l| self.allows.get(l).is_some_and(|rs| rs.iter().any(|r| r == rule)))
    }

    fn emit(&self, out: &mut Vec<Finding>, rule: &'static str, line: usize, message: String) {
        if !self.allowed(line, rule) {
            out.push(Finding { rule, path: self.rel.clone(), line, message });
        }
    }

    /// Name of the nearest `fn` declared at or above `line`.
    fn enclosing_fn(&self, line: usize) -> Option<&str> {
        let hit = self.fns.iter().rev().find(|&&(l, _)| l <= line);
        hit.map(|(_, n)| n.as_str())
    }

    /// L0: a suppression comment without a justification is itself a
    /// finding (and is not itself suppressible).
    fn l0_bad_suppressions(&self, out: &mut Vec<Finding>) {
        for (line, rule) in &self.bad_allows {
            out.push(Finding {
                rule: "L0",
                path: self.rel.clone(),
                line: *line,
                message: format!(
                    "suppression `lint:allow({rule})` lacks a justification — write \
                     `lint:allow({rule}): <why this site is safe>`"
                ),
            });
        }
    }

    /// L1: no `.unwrap()`/`.expect()` on lock results outside
    /// poison-tolerant `lock_*` helpers. A worker that panicked while
    /// holding a lock must not cascade its panic into every other thread
    /// that touches the same lock (`coordinator::lock_metrics` is the
    /// pattern). Test modules are exempt — a poisoned lock in a test should
    /// fail loudly.
    fn l1_lock_unwrap(&self, out: &mut Vec<Finding>) {
        let mut pats = vec![".lock().unwrap()", ".lock().expect("];
        if self.compact.find_from("RwLock", 0).is_some() {
            pats.extend([
                ".read().unwrap()",
                ".read().expect(",
                ".write().unwrap()",
                ".write().expect(",
            ]);
        }
        for pat in pats {
            let mut pos = 0usize;
            while let Some(i) = self.compact.find_from(pat, pos) {
                pos = i + 1;
                let line = self.compact.line_at(i);
                if self.in_tests(line) {
                    continue;
                }
                if self.enclosing_fn(line).is_some_and(|n| n.starts_with("lock_")) {
                    continue;
                }
                self.emit(
                    out,
                    "L1",
                    line,
                    format!(
                        "`{pat}..` on a lock result can cascade a poisoned-lock panic — \
                         route it through a poison-tolerant `lock_*` helper"
                    ),
                );
            }
        }
    }

    /// L2: `partial_cmp(..).unwrap()` panics on NaN (the PR 3 latency-stats
    /// incident). Applies everywhere, tests included — frozen forever.
    fn l2_partial_cmp_unwrap(&self, out: &mut Vec<Finding>) {
        let mut pos = 0usize;
        while let Some(i) = self.compact.find_from(".partial_cmp(", pos) {
            pos = i + 1;
            let Some(after) = self.compact.skip_parens(i + ".partial_cmp".len()) else {
                continue;
            };
            if self.compact.starts_with_at(".unwrap()", after)
                || self.compact.starts_with_at(".expect(", after)
            {
                self.emit(
                    out,
                    "L2",
                    self.compact.line_at(i),
                    "`partial_cmp(..).unwrap()` panics on NaN — use `total_cmp` or handle \
                     the `None`"
                        .to_string(),
                );
            }
        }
    }

    /// L3: `coordinator/scheduler.rs` is a pure state machine — time must
    /// arrive as a parameter (`plan_tick(&mut Router, now)`), never be read
    /// inside. `.elapsed()` is included because it is a hidden
    /// `Instant::now()`. Tests are exempt (they *supply* the timestamps).
    fn l3_scheduler_wall_clock(&self, out: &mut Vec<Finding>) {
        if !self.rel.ends_with("coordinator/scheduler.rs") {
            return;
        }
        for pat in ["Instant::now(", "SystemTime::now(", "thread::sleep(", ".elapsed()"] {
            let mut pos = 0usize;
            while let Some(i) = self.compact.find_from(pat, pos) {
                pos = i + 1;
                let line = self.compact.line_at(i);
                if self.in_tests(line) {
                    continue;
                }
                self.emit(
                    out,
                    "L3",
                    line,
                    format!(
                        "wall-clock read `{pat}` inside the pure scheduler state machine — \
                         time must arrive as a parameter"
                    ),
                );
            }
        }
    }

    /// L4: bare `thread::spawn` only in the worker-pool owners; everything
    /// else uses `thread::scope` so joins are structurally guaranteed.
    fn l4_bare_thread_spawn(&self, out: &mut Vec<Finding>) {
        if L4_SPAWN_ALLOWED.iter().any(|a| self.rel.ends_with(a)) {
            return;
        }
        let mut pos = 0usize;
        while let Some(i) = self.compact.find_from("thread::spawn(", pos) {
            pos = i + 1;
            let line = self.compact.line_at(i);
            if self.in_tests(line) {
                continue;
            }
            self.emit(
                out,
                "L4",
                line,
                "bare `thread::spawn` outside the worker-pool modules — use \
                 `thread::scope` or route the work through the coordinator"
                    .to_string(),
            );
        }
    }

    /// L5: every fallible `pub fn` on the serving surface returns
    /// `Result<_, ServeError>` — one error model, end to end.
    fn l5_serve_error_surface(&self, out: &mut Vec<Finding>) {
        if !L5_SERVE_SURFACE.iter().any(|a| self.rel.ends_with(a)) {
            return;
        }
        let lines: Vec<&str> = self.lexed.code.lines().collect();
        let mut li = 0usize;
        while li < lines.len() {
            let Some(p) = find_pub_fn(lines[li]) else {
                li += 1;
                continue;
            };
            let decl_line = li + 1;
            if self.in_tests(decl_line) {
                li += 1;
                continue;
            }
            let mut sig = lines[li][p..].to_string();
            while !sig.contains('{') && !sig.contains(';') && li + 1 < lines.len() {
                li += 1;
                sig.push(' ');
                sig.push_str(lines[li].trim());
            }
            if let Some(ret) = return_type(&sig) {
                if ret.contains("Result<") && !ret.contains("ServeError") {
                    self.emit(
                        out,
                        "L5",
                        decl_line,
                        format!(
                            "serving-surface `pub fn` returns `{ret}` — fallible public \
                             coordinator APIs must return `Result<_, ServeError>`"
                        ),
                    );
                }
            }
            li += 1;
        }
    }

    /// L7: direct file I/O is confined to the modules that own a disk
    /// surface ([`L7_FILE_IO_ALLOWED`]). A stray `std::fs` call anywhere
    /// else silently grows the set of paths a crash can leave half-written
    /// and bypasses the spill tier's framing/checksum/rollback discipline
    /// (DESIGN.md §14). Tests are exempt — fixtures legitimately build and
    /// tear down temp trees.
    fn l7_file_io_confinement(&self, out: &mut Vec<Finding>) {
        if L7_FILE_IO_ALLOWED.iter().any(|a| self.rel.ends_with(a)) {
            return;
        }
        // One finding per line even when several patterns overlap on the
        // same call (`std::fs::write` matches both the module path and the
        // function pattern).
        let mut flagged: Vec<usize> = Vec::new();
        for pat in [
            "std::fs::",
            "fs::write(",
            "fs::read(",
            "fs::read_to_string(",
            "fs::create_dir",
            "fs::remove_file(",
            "fs::remove_dir_all(",
            "fs::rename(",
            "fs::copy(",
            "File::open(",
            "File::create(",
            "OpenOptions::new(",
        ] {
            let mut pos = 0usize;
            while let Some(i) = self.compact.find_from(pat, pos) {
                pos = i + 1;
                let line = self.compact.line_at(i);
                if self.in_tests(line) || flagged.contains(&line) {
                    continue;
                }
                flagged.push(line);
                self.emit(
                    out,
                    "L7",
                    line,
                    format!(
                        "file I/O `{pat}..` outside the disk-owning modules — route disk \
                         state through `coordinator/spill.rs` or an allowed writer"
                    ),
                );
            }
        }
    }

    /// L8: the loadgen trace generator and virtual-time sim are seeded and
    /// wall-clock-free — same seed, same trace, same report, on any machine
    /// (DESIGN.md §15). A wall-clock read or ambient RNG would silently
    /// break same-seed replayability and the CI-gated policy-comparison
    /// ratio. Scoped to `loadgen/trace.rs` and `loadgen/sim.rs`;
    /// `loadgen/replay.rs` is exempt by scope (wall-clock pacing is its
    /// job), and tests are exempt (they *supply* the base instant).
    fn l8_loadgen_determinism(&self, out: &mut Vec<Finding>) {
        if !(self.rel.ends_with("loadgen/trace.rs") || self.rel.ends_with("loadgen/sim.rs")) {
            return;
        }
        for pat in [
            "Instant::now(",
            "SystemTime::now(",
            "thread::sleep(",
            ".elapsed()",
            "thread_rng(",
            "rand::",
            "RandomState::new(",
        ] {
            let mut pos = 0usize;
            while let Some(i) = self.compact.find_from(pat, pos) {
                pos = i + 1;
                let line = self.compact.line_at(i);
                if self.in_tests(line) {
                    continue;
                }
                self.emit(
                    out,
                    "L8",
                    line,
                    format!(
                        "`{pat}..` in the seeded loadgen trace/sim path — wall clock and \
                         ambient RNG break same-seed replayability; use `SplitMix64` and \
                         the caller-supplied base instant"
                    ),
                );
            }
        }
    }
}

/// Parse `lint:allow(Lk): justification` comments. Returns the justified
/// allows per line plus the allows whose justification is missing/empty.
fn parse_allows(
    comments: &HashMap<usize, String>,
) -> (HashMap<usize, Vec<String>>, Vec<(usize, String)>) {
    let mut allows: HashMap<usize, Vec<String>> = HashMap::new();
    let mut bad = Vec::new();
    for (&line, text) in comments {
        let mut rest = text.as_str();
        while let Some(p) = rest.find("lint:allow(") {
            rest = &rest[p + "lint:allow(".len()..];
            let Some(close) = rest.find(')') else { break };
            let rule = rest[..close].trim().to_string();
            rest = &rest[close + 1..];
            let justified = rest.strip_prefix(':').is_some_and(|j| {
                let end = j.find("lint:allow(").unwrap_or(j.len());
                !j[..end].trim().is_empty()
            });
            if justified {
                allows.entry(line).or_default().push(rule);
            } else {
                bad.push((line, rule));
            }
        }
    }
    bad.sort();
    (allows, bad)
}

/// (line, name) of every `fn` declaration, by a light scan of the code
/// view. Only used to attribute a finding to its nearest enclosing
/// function (the L1 `lock_*` exemption).
fn fn_decls(code: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (idx, l) in code.lines().enumerate() {
        let bytes = l.as_bytes();
        let mut from = 0usize;
        while let Some(p) = l[from..].find("fn ") {
            let at = from + p;
            let boundary = at == 0 || {
                let b = bytes[at - 1];
                !(b.is_ascii_alphanumeric() || b == b'_')
            };
            if boundary {
                let name: String = l[at + 3..]
                    .trim_start()
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !name.is_empty() {
                    out.push((idx + 1, name));
                }
            }
            from = at + 3;
        }
    }
    out
}

/// Byte offset of a `pub fn ` item on this code-view line, if any.
fn find_pub_fn(line: &str) -> Option<usize> {
    let p = line.find("pub fn ")?;
    let boundary = p == 0 || {
        let b = line.as_bytes()[p - 1];
        !(b.is_ascii_alphanumeric() || b == b'_')
    };
    boundary.then_some(p)
}

/// Return type of a (possibly line-joined) `fn` signature: the text after
/// the argument list's `->`, cut at the body / `where` clause. Handles
/// `Fn(..) -> T` bounds inside the generic parameter list and in the
/// arguments.
fn return_type(sig: &str) -> Option<String> {
    let cs: Vec<char> = sig.chars().collect();
    let mut i = sig.find("fn ")? + 3;
    while i < cs.len() && (cs[i].is_alphanumeric() || cs[i] == '_') {
        i += 1;
    }
    if cs.get(i) == Some(&'<') {
        let mut depth = 0i32;
        while i < cs.len() {
            match cs[i] {
                '<' => depth += 1,
                // The `>` of an `->` inside an `Fn(..) -> T` bound must not
                // close a nesting level.
                '>' if i > 0 && cs[i - 1] != '-' => {
                    depth -= 1;
                    if depth == 0 {
                        i += 1;
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
    }
    while i < cs.len() && cs[i] != '(' {
        i += 1;
    }
    let mut depth = 0i32;
    while i < cs.len() {
        match cs[i] {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    i += 1;
                    break;
                }
            }
            _ => {}
        }
        i += 1;
    }
    let rest: String = cs[i..].iter().collect();
    let stop = rest.find(['{', ';']).unwrap_or(rest.len());
    let head = &rest[..stop];
    let arrow = head.find("->")?;
    let mut ret = head[arrow + 2..].trim().to_string();
    if let Some(w) = ret.find(" where ") {
        ret.truncate(w);
    }
    Some(ret.trim().to_string())
}

/// L6: every key in the committed bench baselines must still be a name its
/// producer can emit — each baseline `rows[].name` / `derived` key must
/// match at least one string literal in the emitting code, with `format!`
/// placeholders treated as wildcards. `BENCH_load.baseline.json` is checked
/// against the `rust/src/loadgen` sources (which assemble the SLO report);
/// every other baseline is checked against `benches/hotpath.rs`. Catches
/// renamed or removed rows that `scripts/check_serve_trend.py` silently
/// tolerates ("keys present in only one file are reported but do not
/// fail").
fn l6_bench_baseline_sync(
    bench: Option<&str>,
    baselines: &[(String, String)],
    sources: &[(String, String)],
    out: &mut Vec<Finding>,
) {
    let bench_patterns: Vec<NamePattern> = bench
        .map(|b| lex(b).strings.iter().map(|(_, s)| NamePattern::parse(s)).collect())
        .unwrap_or_default();
    let load_patterns: Vec<NamePattern> = sources
        .iter()
        .filter(|(rel, _)| rel.contains("loadgen/"))
        .flat_map(|(_, text)| {
            lex(text).strings.iter().map(|(_, s)| NamePattern::parse(s)).collect::<Vec<_>>()
        })
        .collect();
    for (path, text) in baselines {
        let is_load = path.ends_with("BENCH_load.baseline.json");
        let (patterns, origin) = if is_load {
            (&load_patterns, "rust/src/loadgen")
        } else {
            if bench.is_none() {
                continue;
            }
            (&bench_patterns, "benches/hotpath.rs")
        };
        let v = match json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                out.push(Finding {
                    rule: "L6",
                    path: path.clone(),
                    line: 1,
                    message: format!("baseline is not valid JSON: {e}"),
                });
                continue;
            }
        };
        let mut names: Vec<String> = Vec::new();
        if let Some(Value::Arr(rows)) = v.get("rows") {
            for r in rows {
                if let Some(Value::Str(n)) = r.get("name") {
                    names.push(n.clone());
                }
            }
        }
        if let Some(Value::Obj(derived)) = v.get("derived") {
            for (k, _) in derived {
                names.push(k.clone());
            }
        }
        for name in names {
            if !patterns.iter().any(|p| p.matches(&name)) {
                out.push(Finding {
                    rule: "L6",
                    path: path.clone(),
                    line: 1,
                    message: format!(
                        "baseline key `{name}` matches no string literal in \
                         {origin} — bench row renamed or removed?"
                    ),
                });
            }
        }
    }
}

/// A bench-name pattern: the literal segments of a (possibly `format!`)
/// string, with `{..}` placeholders as gaps. `{{` / `}}` unescape to
/// literal braces; a string without placeholders matches exactly.
struct NamePattern {
    segs: Vec<String>,
}

impl NamePattern {
    fn parse(s: &str) -> NamePattern {
        let cs: Vec<char> = s.chars().collect();
        let mut segs = vec![String::new()];
        let mut i = 0usize;
        while i < cs.len() {
            match cs[i] {
                '{' if cs.get(i + 1) == Some(&'{') => {
                    segs.last_mut().expect("segs is never empty").push('{');
                    i += 2;
                }
                '}' if cs.get(i + 1) == Some(&'}') => {
                    segs.last_mut().expect("segs is never empty").push('}');
                    i += 2;
                }
                '{' => {
                    while i < cs.len() && cs[i] != '}' {
                        i += 1;
                    }
                    i += 1;
                    segs.push(String::new());
                }
                c => {
                    segs.last_mut().expect("segs is never empty").push(c);
                    i += 1;
                }
            }
        }
        NamePattern { segs }
    }

    fn matches(&self, name: &str) -> bool {
        if self.segs.len() == 1 {
            return self.segs[0] == name;
        }
        let first = &self.segs[0];
        let last = &self.segs[self.segs.len() - 1];
        let Some(tail) = name.strip_prefix(first.as_str()) else {
            return false;
        };
        let Some(mut mid) = tail.strip_suffix(last.as_str()) else {
            return false;
        };
        for seg in &self.segs[1..self.segs.len() - 1] {
            if seg.is_empty() {
                continue;
            }
            match mid.find(seg.as_str()) {
                Some(p) => mid = &mid[p + seg.len()..],
                None => return false,
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(rel: &str, text: &str) -> Vec<Finding> {
        run(&LintInput {
            sources: vec![(rel.to_string(), text.to_string())],
            bench: None,
            baselines: vec![],
        })
    }

    #[test]
    fn l1_flags_lock_unwrap_at_the_chain_line() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock()\n        .unwrap()\n}\n";
        let f = lint_one("rust/src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L1", 2));
    }

    #[test]
    fn l1_exempts_poison_tolerant_lock_helpers() {
        let src =
            "fn lock_metrics(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn l1_skips_cfg_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        let m = \
                   std::sync::Mutex::new(1);\n        let _ = m.lock().unwrap();\n    }\n}\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn l1_read_unwrap_fires_only_in_rwlock_files() {
        let reader = "fn f(x: &Reader) { x.read().unwrap(); }\n";
        assert!(lint_one("rust/src/x.rs", reader).is_empty());
        let rwlock = "use std::sync::RwLock;\nfn f(x: &RwLock<u32>) { x.read().unwrap(); }\n";
        let f = lint_one("rust/src/x.rs", rwlock);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L1");
    }

    #[test]
    fn suppression_with_justification_passes() {
        let src = "fn f(a: f64, b: f64) {\n    // lint:allow(L2): fixture exercises the \
                   legacy path\n    let _ = a.partial_cmp(&b).unwrap();\n}\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn suppression_on_the_flagged_line_passes_too() {
        let src =
            "fn f(a: f64, b: f64) {\n    let _ = a.partial_cmp(&b).unwrap(); // \
             lint:allow(L2): legacy fixture\n}\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn suppression_without_justification_is_l0_and_does_not_suppress() {
        let src = "fn f(a: f64, b: f64) {\n    // lint:allow(L2)\n    let _ = \
                   a.partial_cmp(&b).unwrap();\n}\n";
        let f = lint_one("rust/src/x.rs", src);
        assert!(f.iter().any(|x| x.rule == "L0"));
        assert!(f.iter().any(|x| x.rule == "L2"));
    }

    #[test]
    fn suppression_for_a_different_rule_does_not_apply() {
        let src = "fn f(a: f64, b: f64) {\n    // lint:allow(L1): wrong rule\n    let _ = \
                   a.partial_cmp(&b).unwrap();\n}\n";
        let f = lint_one("rust/src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L2");
    }

    #[test]
    fn l2_fires_even_inside_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        let _ = \
                   1.0f64.partial_cmp(&2.0).unwrap();\n    }\n}\n";
        let f = lint_one("rust/src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L2", 4));
    }

    #[test]
    fn l2_ignores_the_pattern_in_comments_and_strings() {
        let src = "// partial_cmp(..).unwrap() is banned\nfn f() { let _ = \
                   \".partial_cmp(x).unwrap()\"; }\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn l2_matches_across_interior_arguments_and_lines() {
        let src = "fn f(xs: &[f64]) {\n    xs.iter()\n        .max_by(|a, b| \
                   a.partial_cmp(b)\n            .unwrap());\n}\n";
        let f = lint_one("rust/src/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn l3_flags_wall_clock_in_the_scheduler_only() {
        let src = "use std::time::Instant;\nfn tick() { let _ = Instant::now(); }\n";
        let f = lint_one("rust/src/coordinator/scheduler.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L3", 2));
        assert!(lint_one("rust/src/coordinator/mod.rs", src).is_empty());
    }

    #[test]
    fn l3_flags_hidden_elapsed_reads() {
        let src = "fn f(t: std::time::Instant) -> std::time::Duration { t.elapsed() }\n";
        let f = lint_one("rust/src/coordinator/scheduler.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L3");
    }

    #[test]
    fn l4_flags_bare_spawn_outside_worker_modules() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        let f = lint_one("rust/src/algo/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L4");
        assert!(lint_one("rust/src/coordinator/mod.rs", src).is_empty());
        assert!(lint_one("rust/src/engine/mod.rs", src).is_empty());
    }

    #[test]
    fn l4_permits_scoped_spawns() {
        let src = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert!(lint_one("rust/src/algo/x.rs", src).is_empty());
    }

    #[test]
    fn l5_flags_non_serve_error_results_on_the_surface() {
        let src = "pub fn open(&self) -> Result<u32, String> {\n    Err(\"x\".into())\n}\n";
        let f = lint_one("rust/src/coordinator/client.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L5", 1));
        // The identical signature off the serving surface is fine.
        assert!(lint_one("rust/src/figures/x.rs", src).is_empty());
    }

    #[test]
    fn l5_accepts_serve_error_and_infallible_signatures() {
        let src = "pub fn a(&self) -> Result<u32, ServeError> { Ok(1) }\n\
                   pub fn b(&self) -> usize { 1 }\n\
                   pub fn c<F: Fn(u64) -> bool>(&self, f: F) -> Result<(), ServeError> {\n\
                       Ok(())\n\
                   }\n";
        assert!(lint_one("rust/src/coordinator/session.rs", src).is_empty());
    }

    #[test]
    fn l5_handles_multi_line_signatures() {
        let src = "pub fn open(\n    &self,\n    n: usize,\n) -> Result<u32, String> {\n    \
                   Err(\"x\".into())\n}\n";
        let f = lint_one("rust/src/coordinator/api.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L5", 1));
    }

    #[test]
    fn l7_flags_file_io_outside_the_disk_owning_modules() {
        let src = "fn f() { let _ = std::fs::write(\"x\", b\"y\"); }\n";
        let f = lint_one("rust/src/coordinator/batch.rs", src);
        assert_eq!(f.len(), 1, "one finding per line, not one per overlapping pattern");
        assert_eq!((f[0].rule, f[0].line), ("L7", 1));
        // The disk-owning modules are exempt.
        assert!(lint_one("rust/src/coordinator/spill.rs", src).is_empty());
        assert!(lint_one("rust/src/report.rs", src).is_empty());
    }

    #[test]
    fn l7_catches_the_bare_fs_and_open_options_idioms() {
        let src = "use std::fs;\nfn f() { let _ = fs::read_to_string(\"x\"); }\n";
        let f = lint_one("rust/src/quant/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L7", 2));
        let oo = "fn f() { let _ = OpenOptions::new().read(true).open(\"x\"); }\n";
        assert_eq!(lint_one("rust/src/quant/x.rs", oo).len(), 1);
    }

    #[test]
    fn l7_exempts_test_modules_and_honors_suppressions() {
        let tests = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = \
                     std::fs::write(\"x\", b\"y\"); }\n}\n";
        assert!(lint_one("rust/src/coordinator/batch.rs", tests).is_empty());
        let allowed = "fn f() {\n    // lint:allow(L7): one-off debug dump behind a flag\n    \
                       let _ = std::fs::write(\"x\", b\"y\");\n}\n";
        assert!(lint_one("rust/src/coordinator/batch.rs", allowed).is_empty());
    }

    #[test]
    fn l6_accepts_format_wildcards_and_flags_renamed_keys() {
        let bench = "fn main() {\n    emit(\"row_a\");\n    \
                     emit(&format!(\"serve_decode_b{batch}\"));\n}\n";
        let ok = r#"{"rows": [{"name": "serve_decode_b4"}], "derived": {"row_a": 1.0}}"#;
        let bad = r#"{"rows": [{"name": "serve_decode_q4"}], "derived": {}}"#;
        let lint = |baseline: &str| {
            run(&LintInput {
                sources: vec![],
                bench: Some(bench.to_string()),
                baselines: vec![("BENCH_serve.baseline.json".to_string(), baseline.to_string())],
            })
        };
        assert!(lint(ok).is_empty());
        let f = lint(bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L6");
        assert!(f[0].message.contains("serve_decode_q4"));
    }

    #[test]
    fn l6_checks_load_baselines_against_the_loadgen_sources() {
        let slo = "fn rows() {\n    emit(\"load_ttft_interactive_us\");\n    \
                   emit(\"load_interactive_p99_ttft_speedup\");\n}\n";
        let ok = r#"{"rows": [{"name": "load_ttft_interactive_us"}], "derived": {"load_interactive_p99_ttft_speedup": 1.05}}"#;
        let bad = r#"{"rows": [{"name": "load_ttft_renamed_us"}], "derived": {}}"#;
        let lint = |baseline: &str| {
            run(&LintInput {
                sources: vec![("rust/src/loadgen/slo.rs".to_string(), slo.to_string())],
                bench: None,
                baselines: vec![("BENCH_load.baseline.json".to_string(), baseline.to_string())],
            })
        };
        assert!(lint(ok).is_empty());
        let f = lint(bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L6");
        assert!(f[0].message.contains("rust/src/loadgen"));
        assert!(f[0].message.contains("load_ttft_renamed_us"));
    }

    #[test]
    fn l8_flags_wall_clock_and_ambient_rng_in_trace_and_sim_only() {
        let src = "fn generate() { let _ = std::time::Instant::now(); }\n";
        let f = lint_one("rust/src/loadgen/trace.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("L8", 1));
        assert_eq!(lint_one("rust/src/loadgen/sim.rs", src).len(), 1);
        // The live replay paces on the wall clock by design; other modules
        // are covered by L3's scheduler scope, not L8.
        assert!(lint_one("rust/src/loadgen/replay.rs", src).is_empty());
        assert!(lint_one("rust/src/workload/x.rs", src).is_empty());
        let rng = "fn generate() { let mut r = rand::thread_rng(); }\n";
        let f = lint_one("rust/src/loadgen/trace.rs", rng);
        assert!(!f.is_empty() && f.iter().all(|x| x.rule == "L8"), "{f:?}");
    }

    #[test]
    fn l8_exempts_tests_and_honors_suppressions() {
        let tests = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = \
                     std::time::Instant::now(); }\n}\n";
        assert!(lint_one("rust/src/loadgen/sim.rs", tests).is_empty());
        let allowed = "fn f() {\n    // lint:allow(L8): fixture stamps a one-off epoch\n    \
                       let _ = std::time::Instant::now();\n}\n";
        assert!(lint_one("rust/src/loadgen/trace.rs", allowed).is_empty());
    }

    #[test]
    fn l6_flags_unparseable_baselines() {
        let f = run(&LintInput {
            sources: vec![],
            bench: Some("fn main() {}\n".to_string()),
            baselines: vec![("B.json".to_string(), "{not json".to_string())],
        });
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "L6");
    }

    #[test]
    fn name_patterns_unescape_double_braces() {
        let p = NamePattern::parse("a{{b}}c");
        assert!(p.matches("a{b}c"));
        let q = NamePattern::parse("blocked_speedup_b{blk}_ctx{ctx}");
        assert!(q.matches("blocked_speedup_b4_ctx512"));
        assert!(!q.matches("blocked_speedup_b4"));
    }
}
