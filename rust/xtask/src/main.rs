//! `cargo xtask` — repo automation. The one subcommand today is `lint`,
//! the repo-invariant static-analysis pass (rules L0–L8, see `rules.rs`
//! and DESIGN.md §13).
//!
//! Usage:
//!
//! ```text
//! cargo xtask lint            # human-readable findings, path:line: rule: msg
//! cargo xtask lint --json     # {"findings": [...], "total": N} for CI
//! cargo xtask lint --root <p> # lint a tree other than this repo checkout
//! ```
//!
//! Exit codes: 0 clean, 1 findings, 2 usage or I/O error.

mod json;
mod lexer;
mod rules;

use rules::{Finding, LintInput};
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// The committed bench baselines rule L6 checks against their producers
/// (the bench for hotpath/serve, the loadgen sources for load).
const BASELINES: &[&str] = &[
    "BENCH_hotpath.baseline.json",
    "BENCH_load.baseline.json",
    "BENCH_serve.baseline.json",
];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd: Option<&str> = None;
    let mut json_mode = false;
    let mut root: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "lint" if cmd.is_none() => cmd = Some("lint"),
            "--json" => json_mode = true,
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return usage("--root needs a path"),
            },
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    if cmd != Some("lint") {
        return usage("expected a subcommand: lint");
    }
    let root = root.unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("../.."));
    let input = match gather(&root) {
        Ok(i) => i,
        Err(e) => {
            eprintln!("cargo xtask lint: {e}");
            return ExitCode::from(2);
        }
    };
    let findings = rules::run(&input);
    if json_mode {
        println!("{}", render_json(&findings));
    } else {
        for f in &findings {
            println!("{}:{}: {}: {}", f.path, f.line, f.rule, f.message);
        }
        if findings.is_empty() {
            println!("cargo xtask lint: clean ({} files)", input.sources.len());
        } else {
            println!("cargo xtask lint: {} finding(s)", findings.len());
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("cargo xtask: {msg}");
    eprintln!("usage: cargo xtask lint [--json] [--root <path>]");
    ExitCode::from(2)
}

/// Read the lint inputs from a repo checkout rooted at `root`.
fn gather(root: &Path) -> Result<LintInput, String> {
    let src_root = root.join("rust/src");
    let mut files = Vec::new();
    walk_rs(&src_root, &mut files).map_err(|e| format!("walking {}: {e}", src_root.display()))?;
    files.sort();
    let mut sources = Vec::with_capacity(files.len());
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        sources.push((rel, text));
    }
    let bench = fs::read_to_string(root.join("rust/benches/hotpath.rs")).ok();
    let mut baselines = Vec::new();
    for name in BASELINES {
        if let Ok(text) = fs::read_to_string(root.join(name)) {
            baselines.push((name.to_string(), text));
        }
    }
    Ok(LintInput { sources, bench, baselines })
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
            json::escape(f.rule),
            json::escape(&f.path),
            f.line,
            json::escape(&f.message)
        ));
    }
    out.push_str(&format!("], \"total\": {}}}", findings.len()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_root() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../..")
    }

    /// The acceptance criterion: the shipped tree is lint-clean with zero
    /// suppressions.
    #[test]
    fn shipped_tree_is_clean() {
        let input = gather(&repo_root()).expect("gather repo tree");
        assert!(input.sources.len() > 20, "expected the full rust/src tree");
        assert!(input.bench.is_some(), "benches/hotpath.rs must exist for L6");
        assert_eq!(input.baselines.len(), 3, "all three bench baselines must exist");
        let findings = rules::run(&input);
        let rendered: Vec<String> = findings
            .iter()
            .map(|f| format!("{}:{}: {}: {}", f.path, f.line, f.rule, f.message))
            .collect();
        assert!(findings.is_empty(), "lint findings on the shipped tree:\n{}", rendered.join("\n"));
    }

    /// The other acceptance criterion: an injected violation is caught with
    /// a file:line finding and would flip the exit code to 1.
    #[test]
    fn injected_violation_is_caught() {
        let mut input = gather(&repo_root()).expect("gather repo tree");
        input.sources.push((
            "rust/src/injected.rs".to_string(),
            "fn f(a: f64, b: f64) {\n    let _ = a.partial_cmp(&b).unwrap();\n}\n".to_string(),
        ));
        let findings = rules::run(&input);
        assert_eq!(findings.len(), 1, "exactly the injected finding: {findings:?}");
        assert_eq!(findings[0].rule, "L2");
        assert_eq!(findings[0].path, "rust/src/injected.rs");
        assert_eq!(findings[0].line, 2);
    }

    /// L7 end-to-end on the real tree: a stray `std::fs` call in a module
    /// off the disk allowlist is the only finding.
    #[test]
    fn injected_file_io_is_caught_by_l7() {
        let mut input = gather(&repo_root()).expect("gather repo tree");
        input.sources.push((
            "rust/src/sneaky.rs".to_string(),
            "fn f() { let _ = std::fs::write(\"x\", b\"y\"); }\n".to_string(),
        ));
        let findings = rules::run(&input);
        assert_eq!(findings.len(), 1, "exactly the injected finding: {findings:?}");
        assert_eq!(findings[0].rule, "L7");
        assert_eq!(findings[0].path, "rust/src/sneaky.rs");
    }

    #[test]
    fn json_rendering_escapes_and_counts() {
        let findings = vec![Finding {
            rule: "L2",
            path: "rust/src/a \"b\".rs".to_string(),
            line: 7,
            message: "has a \"quote\"".to_string(),
        }];
        let out = render_json(&findings);
        assert!(out.contains("\\\"quote\\\""));
        assert!(out.ends_with("\"total\": 1}"));
        assert!(crate::json::parse(&out).expect("valid JSON").get("total").is_some());
        assert_eq!(render_json(&[]), "{\"findings\": [], \"total\": 0}");
    }
}
