//! A minimal JSON reader and string escaper — the offline build has no
//! serde, and the only JSON the lint pass touches is the two committed
//! bench baselines (machine-written by `benches/hotpath.rs`, rule L6) plus
//! its own `--json` findings output.

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

/// Parse one JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { cs: text.chars().collect(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.cs.len() {
        return Err(format!("trailing input at offset {}", p.i));
    }
    Ok(v)
}

struct Parser {
    cs: Vec<char>,
    i: usize,
}

impl Parser {
    fn skip_ws(&mut self) {
        while self.cs.get(self.i).is_some_and(|c| c.is_ascii_whitespace()) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), String> {
        self.skip_ws();
        if self.cs.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{c}` at offset {}", self.i))
        }
    }

    fn peek(&mut self) -> Option<char> {
        self.skip_ws();
        self.cs.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Value::Str(self.string()?)),
            Some('t') => self.lit("true", Value::Bool(true)),
            Some('f') => self.lit("false", Value::Bool(false)),
            Some('n') => self.lit("null", Value::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at offset {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        for c in word.chars() {
            if self.cs.get(self.i) != Some(&c) {
                return Err(format!("bad literal at offset {}", self.i));
            }
            self.i += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while self
            .cs
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E'))
        {
            self.i += 1;
        }
        let s: String = self.cs[start..self.i].iter().collect();
        s.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            let Some(&c) = self.cs.get(self.i) else {
                return Err("unterminated string".to_string());
            };
            self.i += 1;
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let Some(&e) = self.cs.get(self.i) else {
                        return Err("unterminated escape".to_string());
                    };
                    self.i += 1;
                    match e {
                        'n' => out.push('\n'),
                        't' => out.push('\t'),
                        'r' => out.push('\r'),
                        'u' => {
                            // The baselines never use \u, but accept it.
                            let take = 4.min(self.cs.len() - self.i);
                            let hex: String = self.cs[self.i..self.i + take].iter().collect();
                            self.i += take;
                            let code = u32::from_str_radix(&hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => out.push(other),
                    }
                }
                _ => out.push(c),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect('[')?;
        let mut out = Vec::new();
        if self.peek() == Some(']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            match self.peek() {
                Some(',') => self.i += 1,
                Some(']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected `,` or `]` at offset {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect('{')?;
        let mut out = Vec::new();
        if self.peek() == Some('}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(':')?;
            let val = self.value()?;
            out.push((key, val));
            match self.peek() {
                Some(',') => self.i += 1,
                Some('}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(format!("expected `,` or `}}` at offset {}", self.i)),
            }
        }
    }
}

/// Escape a string for embedding in JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_baseline_shape() {
        let text = r#"{"bench": "serve",
                       "rows": [{"name": "a", "mean": 1.5}],
                       "derived": {"x": 2.0}}"#;
        let v = parse(text).expect("parse");
        let rows = match v.get("rows") {
            Some(Value::Arr(rows)) => rows,
            other => panic!("rows: {other:?}"),
        };
        assert_eq!(rows[0].get("name"), Some(&Value::Str("a".to_string())));
        assert_eq!(v.get("derived").and_then(|d| d.get("x")), Some(&Value::Num(2.0)));
    }

    #[test]
    fn parses_escapes_negatives_and_exponents() {
        let v = parse(r#"{"s": "a\"b\n", "n": -1.5e3, "t": true, "z": null}"#).expect("parse");
        assert_eq!(v.get("s"), Some(&Value::Str("a\"b\n".to_string())));
        assert_eq!(v.get("n"), Some(&Value::Num(-1500.0)));
        assert_eq!(v.get("t"), Some(&Value::Bool(true)));
        assert_eq!(v.get("z"), Some(&Value::Null));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn escape_round_trips_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
