//! Hot-path microbenchmarks (custom harness): the L3 kernels whose
//! performance bounds the whole-figure suite — bit-plane dot products (scalar
//! reference vs the bit-sliced AND+popcount kernel), BESF selection (one-shot
//! vs scratch-reuse), the DRAM model, the lane engine, the multi-head
//! engine, the decode-step rows (session KV-cache append+select vs the
//! per-token full-context rebuild, across context lengths 128→2048), the
//! query-blocked BESF kernel (block sizes {1, 4, 16} vs the per-query sliced
//! reference, across the same context sweep), and the lane-parallel model
//! step (32 lanes, serial vs all cores). Used by the §Perf pass in
//! EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench hotpath` (pass `-- --serve-only` to run just
//! the continuous-batching serve suite, or `-- --popcount-only` to run just
//! the AND+popcount core rows — the nightly simd lane uses the latter with
//! `--features simd` to produce `and_popcount_simd_vs_unrolled`).
//!
//! Besides the human-readable table, results are persisted to
//! `BENCH_hotpath.json` in the working directory (one row per bench plus
//! derived speedup ratios) so the perf trajectory is machine-trackable across
//! PRs. A second suite measures continuous-batching decode cost/token at
//! batch sizes {1, 4, 16} through the scheduler and persists to
//! `BENCH_serve.json`. CI trend-checks BOTH files against the committed
//! baselines via `scripts/check_serve_trend.py` — the derived speedup ratios
//! are machine-independent, so the check is meaningful on any runner.

use bitstopper::algo::{besf_select, BesfScratch, Lats};
use bitstopper::config::LatsConfig;
use bitstopper::engine::{
    default_threads, AttentionEngine, HeadContext, ModelContext, SelectionPolicy,
};
use bitstopper::quant::{margin::BitMargins, BitPlanes, QueryPlanes};
use bitstopper::sim::dram::{Dram, DramConfig};
use bitstopper::sim::qkpu::{assign_round_robin, simulate_lanes, ChainTask, FetchSpec};
use bitstopper::util::stats::Summary;
use bitstopper::util::SplitMix64;
use bitstopper::workload::{DecodeTrace, ModelDecodeTrace, MultiHeadAttn, QuantAttn};
use std::time::Instant;

fn time_it<F: FnMut() -> u64>(
    rows: &mut Vec<(String, Summary)>,
    name: &str,
    iters: usize,
    mut f: F,
) {
    let mut acc = 0u64;
    acc = acc.wrapping_add(f()); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        acc = acc.wrapping_add(f());
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    std::hint::black_box(acc);
    let s = Summary::of(&times);
    println!(
        "bench {name:<32} {:>9.3} ms/iter (p50 {:>9.3}, p95 {:>9.3}, n={})",
        s.mean, s.p50, s.p95, s.n
    );
    rows.push((name.to_string(), s));
}

fn mean_of(rows: &[(String, Summary)], name: &str) -> f64 {
    rows.iter().find(|(n, _)| n == name).map(|(_, s)| s.mean).unwrap_or(f64::NAN)
}

/// Serialize the rows + derived ratios as JSON (no serde in the offline
/// build; every value we emit is a finite f64 or usize, so hand-formatting
/// is safe).
fn write_json(
    path: &str,
    bench: &str,
    unit: &str,
    rows: &[(String, Summary)],
    derived: &[(String, f64)],
) {
    let mut out =
        format!("{{\n  \"bench\": \"{bench}\",\n  \"unit\": \"{unit}\",\n  \"rows\": [\n");
    for (i, (name, s)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean\": {:.6}, \"p50\": {:.6}, \"p95\": {:.6}, \"min\": {:.6}, \"max\": {:.6}, \"n\": {}}}{}\n",
            name,
            s.mean,
            s.p50,
            s.p95,
            s.min,
            s.max,
            s.n,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"derived\": {\n");
    for (i, (name, v)) in derived.iter().enumerate() {
        out.push_str(&format!(
            "    \"{}\": {:.4}{}\n",
            name,
            v,
            if i + 1 < derived.len() { "," } else { "" }
        ));
    }
    out.push_str("  }\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    // `cargo bench --bench hotpath -- --serve-only` skips the hot-path rows
    // for a quick serve-suite-only run; `-- --popcount-only` runs just the
    // AND+popcount core (the nightly simd lane's entry point — no JSON is
    // written, so a partial run never clobbers `BENCH_hotpath.json`).
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--serve-only") {
        serve_bench();
        return;
    }
    if args.iter().any(|a| a == "--popcount-only") {
        println!("== AND+popcount core (popcount-only run) ==\n");
        let mut rows: Vec<(String, Summary)> = Vec::new();
        let mut derived: Vec<(String, f64)> = Vec::new();
        popcount_bench(&mut rows, &mut derived);
        for (name, v) in &derived {
            println!("derived {name:<32} {v:>9.3}");
        }
        return;
    }
    hotpath_bench();
    serve_bench();
}

/// The multi-word AND+popcount reduction shared by the sliced and blocked
/// BESF kernels, measured on a 256k-word (2 MiB/operand) stream. The
/// 4-word-unrolled scalar body (`and_popcount_unrolled`) is always compiled;
/// under `--features simd` the `u64x4` body is timed against it and the
/// ratio lands in `and_popcount_simd_vs_unrolled`. That derived name
/// deliberately lacks the "speedup" substring: the row only exists on simd
/// runs (the allowed-to-fail nightly lane), so it must never arm the trend
/// gate's ratio floor on scalar runners.
fn popcount_bench(rows: &mut Vec<(String, Summary)>, derived: &mut Vec<(String, f64)>) {
    use bitstopper::quant::bitplane::and_popcount_unrolled;
    const WORDS: usize = 256 * 1024;
    const PASSES: usize = 16;
    let mut rng = SplitMix64::new(0xB1B0);
    let a: Vec<u64> = (0..WORDS).map(|_| rng.next_u64()).collect();
    let b: Vec<u64> = (0..WORDS).map(|_| rng.next_u64()).collect();
    time_it(rows, "and_popcount_unrolled_256kw", 20, || {
        let mut acc = 0u64;
        for _ in 0..PASSES {
            let aa = std::hint::black_box(&a[..]);
            let bb = std::hint::black_box(&b[..]);
            acc = acc.wrapping_add(and_popcount_unrolled(aa, bb) as u64);
        }
        acc
    });
    #[cfg(feature = "simd")]
    {
        use bitstopper::quant::bitplane::and_popcount;
        time_it(rows, "and_popcount_simd_256kw", 20, || {
            let mut acc = 0u64;
            for _ in 0..PASSES {
                let aa = std::hint::black_box(&a[..]);
                let bb = std::hint::black_box(&b[..]);
                acc = acc.wrapping_add(and_popcount(aa, bb) as u64);
            }
            acc
        });
        derived.push((
            "and_popcount_simd_vs_unrolled".to_string(),
            mean_of(rows, "and_popcount_unrolled_256kw")
                / mean_of(rows, "and_popcount_simd_256kw"),
        ));
    }
    #[cfg(not(feature = "simd"))]
    {
        let _ = &derived;
        println!("  (simd feature off: and_popcount == unrolled; rerun with --features simd)");
    }
}

fn hotpath_bench() {
    println!("== BitStopper hot-path microbenches ==\n");
    let mut rows: Vec<(String, Summary)> = Vec::new();
    let (seq, dim) = (2048usize, 128usize);
    let qa = QuantAttn::synth(seq, dim, 8, 7);
    let planes = BitPlanes::decompose(&qa.k);
    let lats = Lats::new(LatsConfig::default(), dim, qa.qp.scale, qa.kp.scale);

    // L3 hot path #1: bit-plane decomposition (build-time per context).
    time_it(&mut rows, "bitplane_decompose_2kx128", 10, || {
        let p = BitPlanes::decompose(&qa.k);
        p.keys as u64
    });

    // Query decomposition: the once-per-query cost the sliced kernel adds
    // (the row decomposes all 8 queries per iteration — divide by 8 for the
    // per-query number).
    time_it(&mut rows, "query_planes_decompose_8x128d", 20, || {
        let mut acc = 0u64;
        for q in &qa.queries {
            let qp = QueryPlanes::decompose(q);
            acc = acc.wrapping_add(qp.dim as u64);
        }
        acc
    });

    // L3 hot path #2a: one plane pass over all keys — scalar reference
    // (trailing-zeros walk + per-element query gathers).
    time_it(&mut rows, "plane_dot_round0_all_keys", 20, || {
        let q = &qa.queries[0];
        let mut acc = 0i64;
        for j in 0..seq {
            acc += planes.plane_dot(0, j, q);
        }
        acc as u64
    });

    // L3 hot path #2b: the same pass through the bit-sliced AND+popcount
    // kernel (what BESF/the engine actually run). Acceptance: ≥3× vs #2a.
    let qp0 = QueryPlanes::decompose(&qa.queries[0]);
    time_it(&mut rows, "plane_dot_sliced_round0_all_keys", 20, || {
        let mut acc = 0i64;
        for j in 0..seq {
            acc += planes.plane_dot_sliced(0, j, &qp0);
        }
        acc as u64
    });

    // L3 hot path #3a: full BESF selection for one query, one-shot API
    // (allocates its scratch per call).
    time_it(&mut rows, "besf_select_2kx128", 10, || {
        let margins = BitMargins::generate(&qa.queries[0]);
        let r = besf_select(&qa.queries[0], &planes, &margins, &lats);
        r.survivors.len() as u64
    });

    // L3 hot path #3b: the steady-state serving shape — reused scratch,
    // zero per-query heap allocation in the select loop.
    let mut scratch = BesfScratch::new();
    time_it(&mut rows, "besf_select_scratch_2kx128", 10, || {
        let margins = BitMargins::generate(&qa.queries[0]);
        let r = scratch.select(&qa.queries[0], &planes, &margins, &lats);
        r.survivors.len() as u64
    });

    // L3 hot path #4: DRAM model throughput (100k requests).
    time_it(&mut rows, "dram_model_100k_reads", 10, || {
        let mut d = Dram::new(DramConfig::default());
        let mut rng = SplitMix64::new(3);
        let mut t = 0;
        for _ in 0..100_000 {
            t = d.read(rng.below(1 << 24), 16, t.min(1 << 40));
        }
        t
    });

    // L3 hot path #5: lane engine on a realistic chain mix.
    let chains: Vec<ChainTask> = (0..seq)
        .map(|j| ChainTask {
            steps: (0..3)
                .map(|r| FetchSpec { addr: (r * seq + j) as u64 * 16, bytes: 16, compute: 2 })
                .collect(),
        })
        .collect();
    let lanes = assign_round_robin(chains, 32);
    time_it(&mut rows, "lane_engine_2k_chains", 10, || {
        let mut d = Dram::new(DramConfig::default());
        simulate_lanes(&lanes, &mut d, 0, 64).finish
    });

    // End-to-end: one full accelerator simulation.
    time_it(&mut rows, "simulate_attention_2kx128x8q", 5, || {
        let cfg = bitstopper::config::SimConfig::default();
        bitstopper::sim::simulate_attention(&qa, &cfg).cycles
    });

    // Multi-head engine: head/query-parallel BESF + sparse V across all
    // cores vs one thread (the AttentionEngine throughput-scaling claim).
    // Workers reuse one scratch each, so this is the allocation-free path.
    let mha = MultiHeadAttn::synth(8, 1024, 64, 4, 11);
    let eng = AttentionEngine::new(&mha, LatsConfig::default());
    let survivors_of = |r: &Vec<Vec<bitstopper::engine::QueryResult>>| -> u64 {
        r.iter().flatten().map(|q| q.sel.survivors.len() as u64).sum()
    };
    time_it(&mut rows, "engine_8hx4q_1thread", 5, || {
        survivors_of(&eng.run_all_threads(SelectionPolicy::Lats, 1))
    });
    let cores = default_threads();
    time_it(&mut rows, "engine_8hx4q_all_cores", 5, || {
        survivors_of(&eng.run_all_threads(SelectionPolicy::Lats, cores))
    });
    println!("  (all-cores ran on {cores} threads)");

    // Dense fast path vs the 12-round keep-all it replaced.
    time_it(&mut rows, "engine_dense_8hx4q_all_cores", 5, || {
        survivors_of(&eng.run_all_threads(SelectionPolicy::Dense, cores))
    });

    // Decode-step cost vs context length: the session KV-cache path (one
    // O(dim) append + one selection against cached planes) against the
    // rebuild path (per-token re-quantization of the full K/V context +
    // full 12-plane re-decomposition — what a one-shot request pays). The
    // cached rows must stay ~flat from 128 → 2048 while rebuild grows
    // linearly; acceptance ratios land in the derived block.
    println!();
    // 1 warmup + DECODE_ITERS timed iterations per row; both paths consume
    // the SAME decode steps, so each iteration i of either row measures the
    // identical context length ctx+i+1 — the labeled ctx drifts ≤ DECODE_STEPS
    // tokens for both, symmetrically, keeping the derived ratios unbiased.
    const DECODE_ITERS: usize = 16;
    const DECODE_STEPS: usize = DECODE_ITERS + 1; // every time_it call consumes one step
    for &ctx in &[128usize, 512, 2048] {
        let trace = DecodeTrace::synth(ctx, DECODE_STEPS, 128, 0xDEC + ctx as u64);
        let qa0 = QuantAttn::quantize(&[], &trace.prompt_k, &trace.prompt_v, ctx, 128);
        let mut cached = HeadContext::from_owned(qa0, LatsConfig::default());
        let mut dscratch = BesfScratch::new();
        let mut i_cached = 0usize;
        time_it(&mut rows, &format!("decode_step_cached_ctx{ctx}"), DECODE_ITERS, || {
            let step = &trace.steps[i_cached];
            i_cached += 1;
            cached.append_token(&step.k_row, &step.v_row);
            let qr = cached.decode_scratch(&step.q, &mut dscratch);
            qr.sel.survivors.len() as u64
        });

        let mut k_full = trace.prompt_k.clone();
        let mut v_full = trace.prompt_v.clone();
        let mut i_rebuild = 0usize;
        time_it(&mut rows, &format!("decode_step_rebuild_ctx{ctx}"), DECODE_ITERS, || {
            let step = &trace.steps[i_rebuild];
            i_rebuild += 1;
            k_full.extend_from_slice(&step.k_row);
            v_full.extend_from_slice(&step.v_row);
            let n = ctx + i_rebuild;
            let qa = QuantAttn::quantize(&[step.q.clone()], &k_full, &v_full, n, 128);
            let head = HeadContext::new(&qa, LatsConfig::default());
            let qr = head.run_query_scratch(0, SelectionPolicy::Lats, &mut dscratch);
            qr.sel.survivors.len() as u64
        });
    }

    // Query-blocked BESF: the cache-blocked kernel loads each K-plane row
    // once and serves every still-alive query in the block from it, vs the
    // per-query sliced path re-streaming the planes per query. 16 queries,
    // block sizes {1, 4, 16}, across the context sweep. Block 1 measures the
    // blocking overhead at degenerate width (parity row, not gated); blocks
    // ≥ 4 must win (acceptance: blocked_speedup_b{4,16}_* > 1.0).
    println!();
    for &ctx in &[128usize, 512, 2048] {
        let bqa = QuantAttn::synth(ctx, 128, 16, 0xB10C + ctx as u64);
        let bplanes = BitPlanes::decompose(&bqa.k);
        let blats = Lats::new(LatsConfig::default(), 128, bqa.qp.scale, bqa.kp.scale);
        let qps: Vec<QueryPlanes> =
            bqa.queries.iter().map(|q| QueryPlanes::decompose(q)).collect();
        let mut bscratch = BesfScratch::new();

        // Per-query sliced reference: 16 independent scratch-reuse selects.
        time_it(&mut rows, &format!("besf_sliced_16q_ctx{ctx}"), 10, || {
            let mut acc = 0u64;
            for q in &bqa.queries {
                let margins = BitMargins::generate(q);
                let r = bscratch.select(q, &bplanes, &margins, &blats);
                acc += r.survivors.len() as u64;
            }
            acc
        });

        for &blk in &[1usize, 4, 16] {
            time_it(&mut rows, &format!("besf_block{blk}_16q_ctx{ctx}"), 10, || {
                let mut acc = 0u64;
                for start in (0..16).step_by(blk) {
                    let end = (start + blk).min(16);
                    let out = bscratch.select_block(
                        &qps[start..end],
                        &bqa.queries[start..end],
                        &bplanes,
                        |_r, ml| blats.threshold(ml),
                    );
                    acc += out.iter().map(|r| r.survivors.len() as u64).sum::<u64>();
                }
                acc
            });
        }
    }

    // Lane-parallel model step: a 4-layer × 8-head model (32 lanes) over a
    // 2048-token context, decoded serially vs fanned across all cores
    // through the same `decode_step_threads` entry the serving executor
    // uses. Same queries every iteration (decode is `&self`), so the two
    // rows time identical work.
    println!();
    let mt = ModelDecodeTrace::synth(4, 8, 2048, 1, 64, 0x1A9E);
    let (mk0, mv0) = mt.prompt();
    let mut mctx = ModelContext::open(mt.shape(), LatsConfig::default(), &mk0, &mv0, 2048)
        .expect("model context open");
    let (mqs, mks, mvs) = mt.step_rows(0);
    mctx.append_token(&mks, &mvs).expect("token append");
    let mut mscratch = BesfScratch::new();
    time_it(&mut rows, "model_step_32lanes_ctx2048_t1", 5, || {
        let out = mctx.decode_step_threads(&mqs, &mut mscratch, 1).expect("serial step");
        out.kept.iter().sum::<usize>() as u64
    });
    time_it(&mut rows, "model_step_32lanes_ctx2048_all", 5, || {
        let out = mctx.decode_step_threads(&mqs, &mut mscratch, cores).expect("parallel step");
        out.kept.iter().sum::<usize>() as u64
    });

    let mut derived = vec![
        (
            "sliced_speedup_round0".to_string(),
            mean_of(&rows, "plane_dot_round0_all_keys")
                / mean_of(&rows, "plane_dot_sliced_round0_all_keys"),
        ),
        (
            "scratch_speedup_besf_select".to_string(),
            mean_of(&rows, "besf_select_2kx128") / mean_of(&rows, "besf_select_scratch_2kx128"),
        ),
        (
            "engine_thread_scaling".to_string(),
            mean_of(&rows, "engine_8hx4q_1thread") / mean_of(&rows, "engine_8hx4q_all_cores"),
        ),
        ("threads".to_string(), cores as f64),
        // Per-token decode cost growth 128 → 2048: cached must stay near 1
        // (flat in context length), rebuild grows ~linearly (~16x).
        (
            "decode_cached_growth_128_to_2048".to_string(),
            mean_of(&rows, "decode_step_cached_ctx2048")
                / mean_of(&rows, "decode_step_cached_ctx128"),
        ),
        (
            "decode_rebuild_growth_128_to_2048".to_string(),
            mean_of(&rows, "decode_step_rebuild_ctx2048")
                / mean_of(&rows, "decode_step_rebuild_ctx128"),
        ),
        (
            "decode_session_speedup_ctx2048".to_string(),
            mean_of(&rows, "decode_step_rebuild_ctx2048")
                / mean_of(&rows, "decode_step_cached_ctx2048"),
        ),
    ];
    // Blocked-kernel ratios, all vs the per-query sliced reference at the
    // same context. The b1 row is labeled "parity" (no "speedup" substring)
    // on purpose: it hovers near 1.0 and must not trip the trend gate.
    for &ctx in &[128usize, 512, 2048] {
        let sliced = mean_of(&rows, &format!("besf_sliced_16q_ctx{ctx}"));
        derived.push((
            format!("blocked_b1_parity_ctx{ctx}"),
            sliced / mean_of(&rows, &format!("besf_block1_16q_ctx{ctx}")),
        ));
        for blk in [4usize, 16] {
            derived.push((
                format!("blocked_speedup_b{blk}_ctx{ctx}"),
                sliced / mean_of(&rows, &format!("besf_block{blk}_16q_ctx{ctx}")),
            ));
        }
    }
    // Context-sweep geomeans: the headline blocked-kernel numbers.
    for blk in [4usize, 16] {
        let prod: f64 = [128usize, 512, 2048]
            .iter()
            .map(|ctx| {
                derived
                    .iter()
                    .find(|(n, _)| n == &format!("blocked_speedup_b{blk}_ctx{ctx}"))
                    .map(|(_, v)| *v)
                    .unwrap_or(f64::NAN)
            })
            .product();
        derived.push((format!("blocked_speedup_b{blk}"), prod.powf(1.0 / 3.0)));
    }
    derived.push((
        "model_lane_scaling".to_string(),
        mean_of(&rows, "model_step_32lanes_ctx2048_t1")
            / mean_of(&rows, "model_step_32lanes_ctx2048_all"),
    ));

    // AND+popcount core: always rows the unrolled scalar; adds the simd row
    // + ratio when built with `--features simd` (the nightly lane).
    println!();
    popcount_bench(&mut rows, &mut derived);
    for (name, v) in &derived {
        println!("derived {name:<32} {v:>9.3}");
    }
    write_json("BENCH_hotpath.json", "hotpath", "ms/iter", &rows, &derived);
}

/// Continuous-batching decode throughput vs batch size (DESIGN.md §9): B
/// model sessions (2 layers × 2 heads, 256-token prompts) stream their
/// decode steps through the scheduler concurrently via the typed client
/// surface (DESIGN.md §5); per-token steady-state cost is wall time /
/// tokens. Batched cost/token must land strictly below batch-1 — the whole
/// point of iteration-level batching (idle workers + tick amortization).
/// Rows persist to `BENCH_serve.json` (trend-checked in CI).
fn serve_bench() {
    use bitstopper::coordinator::{
        drive_decode, drive_scored_prefill, drive_spec_decode, EngineBuilder,
    };
    use bitstopper::workload::ModelDecodeTrace;
    use std::time::Duration;

    println!("\n== continuous-batching serve bench ==\n");
    let (layers, heads, dim, ctx, steps) = (2usize, 2usize, 64usize, 256usize, 12usize);
    let reps = 3usize;
    let mut rows: Vec<(String, Summary)> = Vec::new();
    for &batch in &[1usize, 4, 16] {
        let mut per_token_ms = Vec::with_capacity(reps);
        for rep in 0..reps {
            let client = EngineBuilder::new()
                .workers(4)
                .prefill_chunk(512)
                .max_inflight_per_worker(2)
                .build()
                .expect("engine construction");
            let traces: Vec<ModelDecodeTrace> = (0..batch)
                .map(|s| {
                    ModelDecodeTrace::synth(
                        layers,
                        heads,
                        ctx,
                        steps,
                        dim,
                        0x5EA0 + (rep * 100 + s) as u64,
                    )
                })
                .collect();
            // Steady state: every session's stream queued up front; the
            // scheduler interleaves one model step per session per tick.
            // The shared driver times wall from first queued step to last
            // StepDone.
            let report = drive_decode(&client, 0.6, &traces, Duration::from_secs(60))
                .expect("serve drive");
            per_token_ms.push(report.ms_per_token());
            client.shutdown();
        }
        let s = Summary::of(&per_token_ms);
        println!(
            "bench serve_decode_b{batch:<26} {:>9.3} ms/token (p50 {:>9.3}, n={})",
            s.mean, s.p50, s.n
        );
        rows.push((format!("serve_decode_b{batch}"), s));
    }
    // Fused multi-token verify steps (DESIGN.md §10): Q candidate rows per
    // blocked pass, accept-all, 4 concurrent sessions. Cost is per accepted
    // token; Q = 1 runs the same protocol one row at a time and is the
    // sequential baseline the spec speedups divide against.
    let (spec_batch, spec_steps) = (4usize, 16usize);
    for &q in &[1usize, 2, 4, 8] {
        let mut per_token_ms = Vec::with_capacity(reps);
        for rep in 0..reps {
            let client = EngineBuilder::new()
                .workers(4)
                .prefill_chunk(512)
                .max_inflight_per_worker(2)
                .build()
                .expect("engine construction");
            let traces: Vec<ModelDecodeTrace> = (0..spec_batch)
                .map(|s| {
                    ModelDecodeTrace::synth(
                        layers,
                        heads,
                        ctx,
                        spec_steps,
                        dim,
                        0x5EA1 + (rep * 100 + s) as u64,
                    )
                })
                .collect();
            let report = drive_spec_decode(&client, 0.6, &traces, q, Duration::from_secs(60))
                .expect("spec drive");
            per_token_ms.push(report.ms_per_token());
            client.shutdown();
        }
        let s = Summary::of(&per_token_ms);
        println!(
            "bench serve_spec_q{q:<28} {:>9.3} ms/token (p50 {:>9.3}, n={})",
            s.mean, s.p50, s.n
        );
        rows.push((format!("serve_spec_q{q}"), s));
    }
    // Scored prefill: prompt-logprob proxy output, cost per prompt row.
    {
        let mut per_row_ms = Vec::with_capacity(reps);
        for rep in 0..reps {
            let client = EngineBuilder::new()
                .workers(4)
                .prefill_chunk(64)
                .max_inflight_per_worker(2)
                .build()
                .expect("engine construction");
            let traces: Vec<ModelDecodeTrace> = (0..spec_batch)
                .map(|s| {
                    ModelDecodeTrace::synth(
                        layers,
                        heads,
                        ctx,
                        1,
                        dim,
                        0x5EA2 + (rep * 100 + s) as u64,
                    )
                })
                .collect();
            let report = drive_scored_prefill(&client, 0.6, &traces, Duration::from_secs(60))
                .expect("scored prefill drive");
            per_row_ms.push(report.ms_per_row());
            client.shutdown();
        }
        let s = Summary::of(&per_row_ms);
        println!(
            "bench serve_scored_prefill           {:>9.3} ms/row   (p50 {:>9.3}, n={})",
            s.mean, s.p50, s.n
        );
        rows.push(("serve_scored_prefill".to_string(), s));
    }
    // Spill tier (DESIGN.md §14), three angles. (1) Raw wire-format cost:
    // serialize / deserialize of a ctx-512 ModelContext (the demote and
    // promote payloads; derived MB/s lands in the derived block). (2) Cold-
    // step promote latency: a capacity-1 store holding two sessions pays a
    // full demote+promote cycle on every step of the cold one, across ctx
    // {128, 512, 2048}. (3) End-to-end hot:cold decode mix and the
    // idle-overhead parity row (spill configured but never demoting).
    println!();
    let spill_root =
        std::env::temp_dir().join(format!("bitstopper-bench-spill-{}", std::process::id()));
    let payload_mb;
    {
        use bitstopper::coordinator::{ModelStep, SessionStore, SpillStore};
        use std::time::Instant;

        let wt = ModelDecodeTrace::synth(layers, heads, 512, 1, dim, 0x5EA4);
        let (wk, wv) = wt.prompt();
        let wctx = ModelContext::open(wt.shape(), LatsConfig::default(), &wk, &wv, 512)
            .expect("wire-format context");
        let bytes = wctx.to_bytes();
        payload_mb = bytes.len() as f64 / (1024.0 * 1024.0);
        time_it(&mut rows, "serve_spill_serialize_ctx512", 30, || {
            wctx.to_bytes().len() as u64
        });
        time_it(&mut rows, "serve_spill_deserialize_ctx512", 30, || {
            ModelContext::from_bytes(&bytes).expect("roundtrip").context_len() as u64
        });

        for &sctx in &[128usize, 512, 2048] {
            let dir = spill_root.join(format!("promote-ctx{sctx}"));
            SpillStore::validate_dir(&dir).expect("bench spill dir");
            let spill = SpillStore::open(&dir, 0, 1 << 40).expect("bench spill store");
            let mut store = SessionStore::with_policy(1, None).with_spill(spill);
            let now = Instant::now();
            let mt = ModelDecodeTrace::synth(layers, heads, sctx, 1, dim, 0x5EA5);
            let (pk, pv) = mt.prompt();
            for sid in [1u64, 2] {
                store
                    .open(sid, LatsConfig::default(), mt.shape(), &pk, &pv, sctx, now)
                    .expect("bench session open");
            }
            // Decode-only steps keep the context length fixed; session 2 is
            // hot after its open, so the first (warmup) step targets 1.
            let (qs, _, _) = mt.step_rows(0);
            let step = ModelStep::decode_only(qs);
            let mut scratch = BesfScratch::new();
            let mut cold = 1u64;
            time_it(&mut rows, &format!("serve_spill_promote_ctx{sctx}"), 16, || {
                let out = store
                    .step_threads(cold, &step, &mut scratch, 1, now)
                    .expect("cold step");
                cold = 3 - cold;
                out.kept.iter().sum::<usize>() as u64
            });
        }

        // Hot:cold mix — 8 decode streams over 4 workers with one hot slot
        // each, so consecutive steps on a worker alternate its two pinned
        // sessions and every step pays a promote. The idle row serves the
        // stock b4 workload with spill configured but capacity never under
        // pressure: its cost must track serve_decode_b4 (parity ratio in
        // the derived block; the trend gate bounds the row itself).
        for (name, batch, capacity) in
            [("serve_spill_mix_b8", 8usize, 1usize), ("serve_spill_idle_b4", 4, 64)]
        {
            let mut per_token_ms = Vec::with_capacity(reps);
            for rep in 0..reps {
                let dir = spill_root.join(format!("{name}-{rep}"));
                let client = EngineBuilder::new()
                    .workers(4)
                    .prefill_chunk(512)
                    .max_inflight_per_worker(2)
                    .session_capacity(capacity)
                    .idle_ttl(None)
                    .spill_dir(&dir)
                    .build()
                    .expect("engine construction");
                let traces: Vec<ModelDecodeTrace> = (0..batch)
                    .map(|s| {
                        ModelDecodeTrace::synth(
                            layers,
                            heads,
                            ctx,
                            steps,
                            dim,
                            0x5EA6 + (rep * 100 + s) as u64,
                        )
                    })
                    .collect();
                let report = drive_decode(&client, 0.6, &traces, Duration::from_secs(60))
                    .expect("spill mix drive");
                per_token_ms.push(report.ms_per_token());
                client.shutdown();
            }
            let s = Summary::of(&per_token_ms);
            println!(
                "bench {name:<32} {:>9.3} ms/token (p50 {:>9.3}, n={})",
                s.mean, s.p50, s.n
            );
            rows.push((name.to_string(), s));
        }
        let _ = std::fs::remove_dir_all(&spill_root);
    }

    let mut derived = vec![
        (
            "batched_speedup_b4_vs_b1".to_string(),
            mean_of(&rows, "serve_decode_b1") / mean_of(&rows, "serve_decode_b4"),
        ),
        (
            "batched_speedup_b16_vs_b1".to_string(),
            mean_of(&rows, "serve_decode_b1") / mean_of(&rows, "serve_decode_b16"),
        ),
    ];
    for q in [2usize, 4, 8] {
        derived.push((
            format!("spec_per_token_speedup_q{q}"),
            mean_of(&rows, "serve_spec_q1") / mean_of(&rows, &format!("serve_spec_q{q}")),
        ));
    }
    // Spill-tier derived numbers — deliberately no "speedup" substring:
    // MB/s is machine-dependent and the parity/growth ratios hover near a
    // constant, so none of them may arm the trend gate's ratio floor. The
    // serve_spill_* rows themselves carry the regression gate.
    derived.push((
        "spill_serialize_mb_per_s".to_string(),
        payload_mb / (mean_of(&rows, "serve_spill_serialize_ctx512") / 1e3),
    ));
    derived.push((
        "spill_deserialize_mb_per_s".to_string(),
        payload_mb / (mean_of(&rows, "serve_spill_deserialize_ctx512") / 1e3),
    ));
    derived.push((
        "spill_promote_growth_128_to_2048".to_string(),
        mean_of(&rows, "serve_spill_promote_ctx2048")
            / mean_of(&rows, "serve_spill_promote_ctx128"),
    ));
    derived.push((
        "spill_idle_parity_b4".to_string(),
        mean_of(&rows, "serve_decode_b4") / mean_of(&rows, "serve_spill_idle_b4"),
    ));
    for (name, v) in &derived {
        println!("derived {name:<32} {v:>9.3}");
    }
    write_json("BENCH_serve.json", "serve", "ms/token", &rows, &derived);
}
