//! Hot-path microbenchmarks (custom harness): the L3 kernels whose
//! performance bounds the whole-figure suite — bit-plane dot products, BESF
//! selection, the DRAM model and the lane engine. Used by the §Perf pass in
//! EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench hotpath`

use bitstopper::algo::{besf_select, Lats};
use bitstopper::config::LatsConfig;
use bitstopper::engine::{default_threads, AttentionEngine, SelectionPolicy};
use bitstopper::quant::{margin::BitMargins, BitPlanes};
use bitstopper::sim::dram::{Dram, DramConfig};
use bitstopper::sim::qkpu::{assign_round_robin, simulate_lanes, ChainTask, FetchSpec};
use bitstopper::util::stats::Summary;
use bitstopper::util::SplitMix64;
use bitstopper::workload::{MultiHeadAttn, QuantAttn};
use std::time::Instant;

fn time_it<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) {
    let mut acc = 0u64;
    acc = acc.wrapping_add(f()); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        acc = acc.wrapping_add(f());
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    std::hint::black_box(acc);
    let s = Summary::of(&times);
    println!(
        "bench {name:<28} {:>9.3} ms/iter (p50 {:>9.3}, p95 {:>9.3}, n={})",
        s.mean, s.p50, s.p95, s.n
    );
}

fn main() {
    println!("== BitStopper hot-path microbenches ==\n");
    let (seq, dim) = (2048usize, 128usize);
    let qa = QuantAttn::synth(seq, dim, 8, 7);
    let planes = BitPlanes::decompose(&qa.k);
    let lats = Lats::new(LatsConfig::default(), dim, qa.qp.scale, qa.kp.scale);

    // L3 hot path #1: bit-plane decomposition (build-time per context).
    time_it("bitplane_decompose_2kx128", 10, || {
        let p = BitPlanes::decompose(&qa.k);
        p.keys as u64
    });

    // L3 hot path #2: one plane pass over all keys (the BRAT inner loop).
    time_it("plane_dot_round0_all_keys", 20, || {
        let q = &qa.queries[0];
        let mut acc = 0i64;
        for j in 0..seq {
            acc += planes.plane_dot(0, j, q);
        }
        acc as u64
    });

    // L3 hot path #3: full BESF selection for one query.
    time_it("besf_select_2kx128", 10, || {
        let margins = BitMargins::generate(&qa.queries[0]);
        let r = besf_select(&qa.queries[0], &planes, &margins, &lats);
        r.survivors.len() as u64
    });

    // L3 hot path #4: DRAM model throughput (100k requests).
    time_it("dram_model_100k_reads", 10, || {
        let mut d = Dram::new(DramConfig::default());
        let mut rng = SplitMix64::new(3);
        let mut t = 0;
        for _ in 0..100_000 {
            t = d.read(rng.below(1 << 24), 16, t.min(1 << 40));
        }
        t
    });

    // L3 hot path #5: lane engine on a realistic chain mix.
    let chains: Vec<ChainTask> = (0..seq)
        .map(|j| ChainTask {
            steps: (0..3)
                .map(|r| FetchSpec { addr: (r * seq + j) as u64 * 16, bytes: 16, compute: 2 })
                .collect(),
        })
        .collect();
    let lanes = assign_round_robin(chains, 32);
    time_it("lane_engine_2k_chains", 10, || {
        let mut d = Dram::new(DramConfig::default());
        simulate_lanes(&lanes, &mut d, 0, 64).finish
    });

    // End-to-end: one full accelerator simulation.
    time_it("simulate_attention_2kx128x8q", 5, || {
        let cfg = bitstopper::config::SimConfig::default();
        bitstopper::sim::simulate_attention(&qa, &cfg).cycles
    });

    // Multi-head engine: head/query-parallel BESF + sparse V across all
    // cores vs one thread (the AttentionEngine throughput-scaling claim).
    let mha = MultiHeadAttn::synth(8, 1024, 64, 4, 11);
    let eng = AttentionEngine::new(&mha, LatsConfig::default());
    let survivors_of = |r: &Vec<Vec<bitstopper::engine::QueryResult>>| -> u64 {
        r.iter().flatten().map(|q| q.sel.survivors.len() as u64).sum()
    };
    time_it("engine_8hx4q_1thread", 5, || {
        survivors_of(&eng.run_all_threads(SelectionPolicy::Lats, 1))
    });
    let cores = default_threads();
    time_it("engine_8hx4q_all_cores", 5, || {
        survivors_of(&eng.run_all_threads(SelectionPolicy::Lats, cores))
    });
    println!("  (all-cores ran on {cores} threads)");
}
