//! Paper-figure benchmark suite (custom harness — criterion is unavailable
//! offline). One bench per table/figure: each regenerates its figure while
//! timing the full simulation stack, printing both the wall-time statistics
//! and the figure rows (the numbers the paper reports).
//!
//! Run: `cargo bench` (or `cargo bench -- 11` to filter by name substring).

use bitstopper::figures;
use bitstopper::util::stats::Summary;
use std::time::Instant;

fn bench<F: FnMut() -> bitstopper::report::Table>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    let table = f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let t = f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
        std::hint::black_box(t);
    }
    let s = Summary::of(&times);
    println!(
        "bench {name:<22} {:>8.1} ms/iter (p50 {:>8.1}, p95 {:>8.1}, n={})",
        s.mean, s.p50, s.p95, s.n
    );
    println!("{}", table.render());
}

fn main() {
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let run = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);

    println!("== BitStopper paper-figure bench suite ==\n");
    if run("table1") {
        bench("table1_config", 3, figures::table1);
    }
    if run("fig3a") {
        bench("fig3a_power_split", 2, figures::fig3a);
    }
    if run("fig3b") {
        bench("fig3b_selection_acc", 2, figures::fig3b);
    }
    if run("fig10") {
        bench("fig10_complexity", 1, figures::fig10);
    }
    if run("fig11") {
        bench("fig11_dram_access", 1, figures::fig11);
    }
    if run("fig12") {
        bench("fig12_speedup_energy", 1, figures::fig12);
    }
    if run("fig13a") {
        bench("fig13a_alpha_sweep", 1, figures::fig13a);
    }
    if run("fig13b") {
        bench("fig13b_breakdown", 1, figures::fig13b);
    }
    if run("fig14") {
        bench("fig14_area_power", 3, figures::fig14);
    }
    if run("headline") {
        bench("headline_claims", 1, figures::headline);
    }
    if run("ablation") {
        bench("ablation_scoreboard", 1, figures::ablations::ablation_scoreboard);
        bench("ablation_dram_latency", 1, figures::ablations::ablation_dram_latency);
        bench("ablation_radius", 1, figures::ablations::ablation_radius);
        bench("ablation_lanes", 1, figures::ablations::ablation_lanes);
    }
}
