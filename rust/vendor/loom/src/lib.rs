//! Std-backed stand-in for the [`loom`](https://docs.rs/loom) model
//! checker, vendored because the offline build can fetch nothing (the same
//! precedent as `rust/vendor/anyhow`).
//!
//! The surface mirrors the subset of loom's API the repo's
//! `rust/tests/loom_protocols.rs` uses — `loom::model`, `loom::sync::*`,
//! `loom::thread::*` — so the tests compile unchanged against the real
//! crate. Semantics differ in one important way: real loom runs the model
//! closure once per *distinct interleaving* of the synchronization
//! operations inside it; this shim runs it exactly once under the OS
//! scheduler. The protocol tests are therefore written so every assertion
//! is interleaving-independent (they assert agreement between an op log
//! and the observed outcome, not a specific schedule), which makes them
//! meaningful single-execution race tests here and exhaustive
//! model-checking tests once the real crate is swapped in via
//! `Cargo.toml`'s `[target.'cfg(loom)'.dependencies]` entry.

/// Run a concurrent model. Real loom explores every interleaving; this
/// shim executes the closure once.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    f();
}

/// Mirrors `loom::sync` with the std equivalents.
pub mod sync {
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};

    /// Mirrors `loom::sync::atomic`.
    pub mod atomic {
        pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
    }

    /// Mirrors `loom::sync::mpsc`.
    pub mod mpsc {
        pub use std::sync::mpsc::{channel, Receiver, Sender};
    }
}

/// Mirrors `loom::thread` with the std equivalents.
pub mod thread {
    pub use std::thread::{spawn, yield_now, JoinHandle};
}
