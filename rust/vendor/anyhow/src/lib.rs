//! Minimal offline stand-in for the `anyhow` crate (the build image has no
//! crates.io access). Implements exactly the subset this workspace uses:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], [`ensure!`] and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `impl From<E: std::error::Error> for Error` coherent, which in turn makes
//! `?` work on any std error inside an `anyhow::Result` function.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error carrying a cause chain (outermost message first in
/// [`Display`], causes listed in order).
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), chain: Vec::new() }
    }

    /// Wrap with an outer context message (the previous message becomes the
    /// first cause).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Self { msg: c.to_string(), chain }
    }

    /// The cause chain, outermost first (does not include the top message).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        // `{:#}` renders the full chain inline, as the real crate does.
        if f.alternate() {
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { msg: e.to_string(), chain }
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a message literal, a printable expression, or a
/// format string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Error = Error::from(io_err()).context("opening file").context("loading model");
        assert_eq!(e.to_string(), "loading model");
        let full = format!("{e:#}");
        assert_eq!(full, "loading model: opening file: gone");
    }

    #[test]
    fn context_on_option() {
        let x: Option<u32> = None;
        let e = x.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let y: Option<u32> = Some(3);
        assert_eq!(y.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros_cover_all_arms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let msg = String::from("dynamic");
        let b = anyhow!(msg);
        assert_eq!(b.to_string(), "dynamic");
        let c = anyhow!("x = {}", 42);
        assert_eq!(c.to_string(), "x = 42");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("gone"));
    }
}
