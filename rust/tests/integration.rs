//! Cross-module integration tests: functional model ↔ cycle simulator ↔
//! baselines ↔ quality pipeline, on shared workloads.

use bitstopper::algo::{besf_select, Lats};
use bitstopper::attention::{attention_int12, attention_int12_sparse, rel_err};
use bitstopper::baselines::{simulate_sanger, simulate_sofa, simulate_tokenpicker, SofaMode};
use bitstopper::config::{Features, LatsConfig, SimConfig};
#[allow(unused_imports)]
use bitstopper::config::ModelShape;
use bitstopper::quant::{margin::BitMargins, BitPlanes};
use bitstopper::sim::simulate_attention;
use bitstopper::workload::QuantAttn;

fn workload(seq: usize, dim: usize, queries: usize, seed: u64) -> QuantAttn {
    QuantAttn::synth(seq, dim, queries, seed)
}

/// The end-to-end ordering the paper's headline claims rest on:
/// BitStopper < SOFA* < Sanger < Dense in cycles AND dram traffic.
#[test]
fn headline_ordering_on_llama_shape() {
    let qa = workload(2048, 128, 4, 0xE2E);
    let cfg = SimConfig::default();
    let mut dense_cfg = cfg.clone();
    dense_cfg.features = Features::DENSE;

    let dense = simulate_attention(&qa, &dense_cfg);
    let bs = simulate_attention(&qa, &cfg);
    let sanger = simulate_sanger(&qa, &cfg);
    let sofa = simulate_sofa(&qa, &cfg, SofaMode::Finetuned);

    assert!(bs.cycles < sanger.cycles, "bs {} sanger {}", bs.cycles, sanger.cycles);
    assert!(bs.cycles < sofa.cycles, "bs {} sofa {}", bs.cycles, sofa.cycles);
    assert!(sanger.cycles < dense.cycles);
    assert!(sofa.cycles < dense.cycles);
    assert!(bs.complexity.dram_bits() < sanger.complexity.dram_bits());
    assert!(bs.complexity.dram_bits() < sofa.complexity.dram_bits());

    // Energy ordering must match too (Fig. 12).
    assert!(bs.energy.total_pj() < sanger.energy.total_pj());
    assert!(bs.energy.total_pj() < sofa.energy.total_pj());
    assert!(bs.energy.total_pj() < dense.energy.total_pj());
}

/// Paper §V-C: DRAM fraction of energy — Sanger ~67 %, SOFA ~62 %,
/// BitStopper limits it to ~38 %. We assert the *ordering* and that
/// BitStopper's fraction is decisively lower.
#[test]
fn dram_energy_fraction_ordering() {
    let qa = workload(2048, 64, 4, 0xD0);
    let cfg = SimConfig::default();
    let bs = simulate_attention(&qa, &cfg);
    let sanger = simulate_sanger(&qa, &cfg);
    let sofa = simulate_sofa(&qa, &cfg, SofaMode::Finetuned);
    assert!(
        bs.energy.dram_fraction() < sanger.energy.dram_fraction(),
        "bs {} sanger {}",
        bs.energy.dram_fraction(),
        sanger.energy.dram_fraction()
    );
    assert!(bs.energy.dram_fraction() < sofa.energy.dram_fraction());
}

/// The simulator's keep-rate and traffic must agree with the functional
/// model run standalone (same decisions, two code paths).
#[test]
fn simulator_agrees_with_functional_model() {
    let qa = workload(256, 64, 3, 0x51);
    let cfg = SimConfig::default();
    let r = simulate_attention(&qa, &cfg);

    let planes = BitPlanes::decompose(&qa.k);
    let lats = Lats::new(LatsConfig::default(), 64, qa.qp.scale, qa.kp.scale);
    let mut survivors = 0usize;
    let mut k_bits = 0u64;
    for q in &qa.queries {
        let margins = BitMargins::generate(q);
        let sel = besf_select(q, &planes, &margins, &lats);
        survivors += sel.survivors.len();
        k_bits += sel.complexity.k_bits;
    }
    let keep = survivors as f64 / (3.0 * 256.0);
    assert!((r.keep_rate - keep).abs() < 1e-12);
    assert_eq!(r.complexity.k_bits, k_bits);
}

/// Quality loop: pruned attention outputs stay close to dense INT12 outputs
/// at the default α on realistic distributions (the +0.1 PPL budget's
/// mechanical counterpart).
#[test]
fn pruned_outputs_track_dense_outputs() {
    let qa = workload(512, 64, 8, 0x0A11);
    let planes = BitPlanes::decompose(&qa.k);
    let lats = Lats::new(LatsConfig::default(), 64, qa.qp.scale, qa.kp.scale);
    let mut errs = vec![];
    for q in &qa.queries {
        let margins = BitMargins::generate(q);
        let sel = besf_select(q, &planes, &margins, &lats);
        let dense = attention_int12(q, &qa.k, &qa.v, qa.qp, qa.kp, qa.vp);
        let sparse = attention_int12_sparse(
            q, &qa.k, &qa.v, qa.qp, qa.kp, qa.vp, &sel.survivors,
        );
        errs.push(rel_err(&sparse, &dense) as f64);
    }
    let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mean_err < 0.12, "mean rel err {mean_err}");
}

/// TokenPicker sits between Sanger and BitStopper on K traffic (finer than
/// Sanger's full-fetch, coarser than 1-bit).
#[test]
fn tokenpicker_traffic_ordering() {
    let qa = workload(1024, 64, 4, 0x70);
    let cfg = SimConfig::default();
    let bs = simulate_attention(&qa, &cfg);
    let tp = simulate_tokenpicker(&qa, &cfg);
    let sanger = simulate_sanger(&qa, &cfg);
    assert!(bs.complexity.k_bits < tp.complexity.k_bits);
    assert!(tp.complexity.k_bits < sanger.complexity.k_bits);
}

/// Speedup grows with sequence length for BitStopper vs dense (paper §V-C).
#[test]
fn speedup_scales_with_sequence_length() {
    let cfg = SimConfig::default();
    let mut dense_cfg = cfg.clone();
    dense_cfg.features = Features::DENSE;
    let mut speedups = vec![];
    for seq in [256usize, 1024, 4096] {
        let qa = workload(seq, 64, 2, 0x5E0 + seq as u64);
        let d = simulate_attention(&qa, &dense_cfg);
        let b = simulate_attention(&qa, &cfg);
        speedups.push(b.speedup_over(&d));
    }
    assert!(
        speedups[2] > speedups[0],
        "4k speedup {} should beat 256 speedup {}",
        speedups[2],
        speedups[0]
    );
}
