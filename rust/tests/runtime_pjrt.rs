//! Integration: the PJRT runtime must load, compile and execute the AOT
//! artifacts, and the numerics must agree with the Rust reference attention.
//!
//! Requires `make artifacts` to have produced `artifacts/` (skipped with a
//! message otherwise, so `cargo test` works on a fresh checkout).

use bitstopper::attention::{attention_int12, rel_err};
use bitstopper::quant::quantize;
use bitstopper::quant::IntMatrix;
use bitstopper::runtime::{default_artifact_dir, ArtifactKind, Runtime};
use bitstopper::util::SplitMix64;

fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.txt").exists()
}

fn synth(seq: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..seq * dim).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..seq * dim).map(|_| rng.normal() as f32).collect();
    (q, k, v)
}

#[test]
fn runtime_loads_all_manifest_artifacts() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::new().expect("PJRT CPU client");
    let n = rt.load_dir(&default_artifact_dir()).expect("load artifacts");
    assert!(n >= 3, "expected several artifacts, got {n}");
    assert!(rt.lookup(ArtifactKind::Dense, 256, 64, 0.0).is_some());
    assert!(rt.lookup(ArtifactKind::BitStopper, 256, 64, 0.6).is_some());
}

#[test]
fn dense_artifact_matches_rust_int12_reference() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load_dir(&default_artifact_dir()).unwrap();
    let art = rt.lookup(ArtifactKind::Dense, 256, 64, 0.0).expect("dense 256x64");
    let (q, k, v) = synth(256, 64, 0xAA);
    let valid = vec![1.0f32; 256];
    let out = art.run(&q, &k, &v, &valid).expect("execute");
    assert_eq!(out.out.len(), 64);
    assert_eq!(out.kept(), 256, "dense keeps everything");

    // Rust INT12 reference (V unquantized in the artifact → compare loosely).
    let (qi, qp) = quantize(&q);
    let (ki, kp) = quantize(&k);
    let (vi, vp) = quantize(&v);
    let km = IntMatrix::new(256, 64, ki);
    let vm = IntMatrix::new(256, 64, vi);
    let want = attention_int12(&qi, &km, &vm, qp, kp, vp);
    let err = rel_err(&out.out, &want);
    assert!(err < 5e-3, "artifact vs rust reference rel err {err}");
}

#[test]
fn bitstopper_artifact_prunes_and_tracks_dense() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load_dir(&default_artifact_dir()).unwrap();
    let dense = rt.lookup(ArtifactKind::Dense, 256, 64, 0.0).unwrap();
    let sparse = rt.lookup(ArtifactKind::BitStopper, 256, 64, 0.6).unwrap();
    assert!((sparse.info.alpha - 0.6).abs() < 1e-9);

    let (q, k, v) = synth(256, 64, 0xBB);
    let valid = vec![1.0f32; 256];
    let d = dense.run(&q, &k, &v, &valid).unwrap();
    let s = sparse.run(&q, &k, &v, &valid).unwrap();
    assert!(s.kept() < 256, "BESF/LATS must prune gaussian QKV");
    assert!(s.kept() >= 1);
    // Unstructured gaussian attention is near-uniform — the hardest case for
    // any top-band policy — so only a loose tracking bound applies here (the
    // realistic-distribution quality bound lives in tests/integration.rs).
    let err = rel_err(&s.out, &d.out);
    assert!(err < 0.5, "sparse output should roughly track dense, rel err {err}");
    assert!(s.out.iter().all(|x| x.is_finite()));
}

#[test]
fn bitstopper_artifact_selection_matches_rust_besf() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use bitstopper::algo::{besf_select, Lats};
    use bitstopper::config::LatsConfig;
    use bitstopper::quant::{margin::BitMargins, BitPlanes};

    let mut rt = Runtime::new().unwrap();
    rt.load_dir(&default_artifact_dir()).unwrap();
    let art = rt.lookup(ArtifactKind::BitStopper, 128, 32, 0.6).expect("128x32 artifact");

    let (q, k, v) = synth(128, 32, 0xCC);
    let valid = vec![1.0f32; 128];
    let got = art.run(&q, &k, &v, &valid).unwrap();

    // Reproduce the in-graph selection with the Rust functional model.
    let (qi, qp) = quantize(&q);
    let (ki, kp) = quantize(&k);
    let km = IntMatrix::new(128, 32, ki);
    let planes = BitPlanes::decompose(&km);
    let margins = BitMargins::generate(&qi);
    let lats = Lats::new(LatsConfig { alpha: 0.6, radius: 5.0 }, 32, qp.scale, kp.scale);
    let want = besf_select(&qi, &planes, &margins, &lats);

    let got_set: Vec<usize> =
        got.mask.iter().enumerate().filter(|(_, &m)| m > 0.5).map(|(j, _)| j).collect();
    assert_eq!(got_set, want.survivors, "cross-layer BESF agreement (JAX vs Rust)");
}

#[test]
fn invalid_shape_rejected() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load_dir(&default_artifact_dir()).unwrap();
    let art = rt.lookup(ArtifactKind::Dense, 256, 64, 0.0).unwrap();
    let bad = art.run(&[0.0; 8], &[0.0; 8], &[0.0; 8], &[0.0; 8]);
    assert!(bad.is_err());
}
