//! Coordinator end-to-end over the PJRT runtime: submit batched attention
//! requests through the engine with a real artifact-backed executor and
//! validate responses + metrics. Skips when artifacts are missing.

use bitstopper::coordinator::{AttnExecutor, AttnRequest, BatchConfig, Engine};
use bitstopper::runtime::{default_artifact_dir, ArtifactKind, Runtime};
use bitstopper::util::SplitMix64;
use std::time::Duration;

/// PJRT-backed executor; constructed lazily inside its worker thread (the
/// PJRT client is not `Send`).
struct PjrtExecutor {
    rt: Option<Runtime>,
}

impl PjrtExecutor {
    fn new() -> Self {
        Self { rt: None }
    }

    fn runtime(&mut self) -> anyhow::Result<&Runtime> {
        if self.rt.is_none() {
            let mut rt = Runtime::new()?;
            rt.load_dir(&default_artifact_dir())?;
            self.rt = Some(rt);
        }
        Ok(self.rt.as_ref().unwrap())
    }
}

impl AttnExecutor for PjrtExecutor {
    fn execute(&mut self, req: &AttnRequest) -> anyhow::Result<(Vec<f32>, usize)> {
        let (kind, seq, dim, alpha) = (req.kind, req.seq, req.dim, req.alpha);
        let q = req.q.clone();
        let k = req.k.clone();
        let v = req.v.clone();
        let valid = req.valid.clone();
        let rt = self.runtime()?;
        let art = rt
            .lookup(kind, seq, dim, alpha)
            .ok_or_else(|| anyhow::anyhow!("no artifact for {kind:?} {seq}x{dim}"))?;
        let out = art.run(&q, &k, &v, &valid)?;
        let kept = out.kept();
        Ok((out.out, kept))
    }
}

fn mk_request(kind: ArtifactKind, seq: usize, dim: usize, seed: u64) -> AttnRequest {
    let mut rng = SplitMix64::new(seed);
    AttnRequest {
        id: 0,
        kind,
        alpha: 0.6,
        seq,
        dim,
        q: (0..dim).map(|_| rng.normal() as f32).collect(),
        k: (0..seq * dim).map(|_| rng.normal() as f32).collect(),
        v: (0..seq * dim).map(|_| rng.normal() as f32).collect(),
        valid: vec![1.0; seq],
    }
}

#[test]
fn coordinator_serves_mixed_artifact_requests() {
    if !default_artifact_dir().join("manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::start(
        2,
        BatchConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        PjrtExecutor::new,
    );

    let mut rxs = vec![];
    for i in 0..24 {
        let kind = if i % 2 == 0 { ArtifactKind::BitStopper } else { ArtifactKind::Dense };
        let (seq, dim) = if i % 3 == 0 { (128, 32) } else { (256, 64) };
        rxs.push((kind, dim, engine.submit(mk_request(kind, seq, dim, i))));
    }
    for (kind, dim, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.out.len(), dim);
        assert!(resp.out.iter().all(|x| x.is_finite()));
        if kind == ArtifactKind::BitStopper {
            assert!(resp.kept >= 1);
        }
    }
    let m = engine.metrics();
    assert_eq!(m.completed, 24);
    assert_eq!(m.errors, 0);
    assert!(m.mean_batch_size >= 1.0);
    assert!(m.throughput_rps > 0.0);
    engine.shutdown();
}

#[test]
fn coordinator_reports_latency_metrics() {
    if !default_artifact_dir().join("manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::start(1, BatchConfig::default(), PjrtExecutor::new);
    for i in 0..8 {
        engine
            .submit_blocking(mk_request(ArtifactKind::Dense, 128, 32, 100 + i))
            .unwrap();
    }
    let m = engine.metrics();
    assert!(m.mean_latency_us > 0.0);
    assert!(m.p95_latency_us >= m.mean_latency_us * 0.5);
    engine.shutdown();
}
