//! Coordinator end-to-end over the PJRT runtime: submit batched attention
//! requests through the typed client surface with the artifact-backed
//! [`PjrtExecutor`] and validate responses + metrics. Skips when artifacts
//! are missing.

use bitstopper::coordinator::{
    AttnRequest, BatchConfig, Client, EngineBuilder, PjrtExecutor, ServeError,
};
use bitstopper::runtime::{default_artifact_dir, ArtifactKind};
use bitstopper::util::SplitMix64;
use std::time::Duration;

fn pjrt_client(workers: usize, cfg: BatchConfig) -> Client {
    EngineBuilder::new()
        .workers(workers)
        .batch(cfg)
        .build_with(PjrtExecutor::new)
        .expect("engine construction")
}

fn mk_request(kind: ArtifactKind, seq: usize, dim: usize, seed: u64) -> AttnRequest {
    let mut rng = SplitMix64::new(seed);
    AttnRequest {
        id: 0,
        kind,
        alpha: 0.6,
        seq,
        dim,
        q: (0..dim).map(|_| rng.normal() as f32).collect(),
        k: (0..seq * dim).map(|_| rng.normal() as f32).collect(),
        v: (0..seq * dim).map(|_| rng.normal() as f32).collect(),
        valid: vec![1.0; seq],
    }
}

#[test]
fn coordinator_serves_mixed_artifact_requests() {
    if !default_artifact_dir().join("manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let client = pjrt_client(2, BatchConfig { max_batch: 8, max_wait: Duration::from_millis(1) });

    let mut tickets = vec![];
    for i in 0..24 {
        let kind = if i % 2 == 0 { ArtifactKind::BitStopper } else { ArtifactKind::Dense };
        let (seq, dim) = if i % 3 == 0 { (128, 32) } else { (256, 64) };
        tickets.push((kind, dim, client.submit(mk_request(kind, seq, dim, i)).expect("submit")));
    }
    for (kind, dim, ticket) in tickets {
        let resp = ticket.recv_timeout(Duration::from_secs(120)).expect("response");
        assert_eq!(resp.out.len(), dim);
        assert!(resp.out.iter().all(|x| x.is_finite()));
        if kind == ArtifactKind::BitStopper {
            assert!(resp.kept >= 1);
        }
    }
    let m = client.metrics();
    assert_eq!(m.completed, 24);
    assert_eq!(m.errors, 0);
    assert!(m.mean_batch_size >= 1.0);
    assert!(m.throughput_rps > 0.0);
    client.shutdown();
}

#[test]
fn coordinator_reports_latency_metrics() {
    if !default_artifact_dir().join("manifest.txt").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let client = pjrt_client(1, BatchConfig::default());
    for i in 0..8 {
        client
            .submit_blocking(mk_request(ArtifactKind::Dense, 128, 32, 100 + i))
            .unwrap();
    }
    let m = client.metrics();
    assert!(m.mean_latency_us > 0.0);
    assert!(m.p95_latency_us >= m.mean_latency_us * 0.5);
    client.shutdown();
}

#[test]
fn pjrt_executor_model_rejection_reaches_the_client_typed() {
    // No artifacts needed: the ExecutorUnsupported rejection (ROADMAP "PJRT
    // executor parity") happens before the runtime loads, and the typed
    // error must arrive on the session handle's stream end to end.
    let client = pjrt_client(1, BatchConfig::default());
    let shape = bitstopper::engine::ModelShape::single(4);
    let mut h = client.open_model_session(0.6, shape).expect("open");
    h.prefill(bitstopper::coordinator::ModelPrompt::single(
        4,
        2,
        vec![0.1; 8],
        vec![0.1; 8],
    ))
    .expect("queue prefill");
    assert_eq!(
        h.wait_prefilled(Duration::from_secs(10)).unwrap_err(),
        ServeError::ExecutorUnsupported { op: "model sessions" }
    );
    client.shutdown();
}
