#![cfg(loom)]
//! Model-checked concurrency protocols (run with
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_protocols`):
//!
//! 1. **Pending-candidate stash/accept/invalidate** — a speculative
//!    verify block ([`SessionStore::step_block`]) stashes candidate K/V
//!    rows server-side; a racing append-only step must *invalidate* them
//!    so a later `accept(n)` can never append stale rows
//!    (DESIGN.md §10's invalidation rule, §13 "Correctness tooling").
//! 2. **Eviction → pin-release handoff** — a store eviction arriving as
//!    [`Feedback::Evicted`] while the client races more work must end
//!    with the router pin released, the scheduler empty, and every
//!    enqueued unit either dispatched or failed with a typed error on
//!    the stream — never a silent gap (DESIGN.md §9).
//!
//! The `loom` dependency resolves to `rust/vendor/loom`, a std-backed
//! shim (the offline build can fetch nothing): each model runs once
//! under the OS scheduler instead of once per interleaving. Every
//! assertion below is therefore written interleaving-independent — it
//! checks agreement between an op log and the observed outcome, not a
//! specific schedule — so the tests are meaningful race tests today and
//! become exhaustive model checks by swapping the path dependency for
//! the real crate.

use bitstopper::algo::BesfScratch;
use bitstopper::config::LatsConfig;
use bitstopper::coordinator::scheduler::Dispatch;
use bitstopper::coordinator::{
    EvictReason, Feedback, ModelPrompt, ModelStep, ModelStepBlock, Router, SchedConfig, Scheduler,
    ServeError, SessionEvent, SessionStore,
};
use bitstopper::engine::ModelShape;
use bitstopper::util::SplitMix64;
use loom::sync::{Arc, Mutex};
use loom::thread;
use std::time::Instant;

/// Deterministic non-degenerate f32 rows (quantization needs a non-zero
/// calibration scale; loom models cannot read entropy sources).
fn rows(seed: u64, n: usize, dim: usize) -> Vec<Vec<f32>> {
    let mut rng = SplitMix64::new(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut row = Vec::with_capacity(dim);
        for _ in 0..dim {
            row.push((rng.next_u64() % 2000) as f32 / 1000.0 - 1.0);
        }
        out.push(row);
    }
    out
}

/// Per-lane flat chunk buffers (`[rows × dim]` per lane), the
/// [`SessionStore::open`] prefill layout.
fn flat_chunk(seed: u64, lanes: usize, n_rows: usize, dim: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(lanes);
    for l in 0..lanes {
        out.push(rows(seed ^ (l as u64 + 1), n_rows, dim).concat());
    }
    out
}

/// An empty-result worker ack for a dispatch (protocol 2 only exercises
/// the scheduler's bookkeeping, not the model math).
fn done(d: &Dispatch) -> Feedback {
    Feedback::Done { worker: d.worker, session: d.job.session(), kept: 0, context: 0 }
}

/// Protocol 1: `accept(n)` after an invalidating append must fail (and
/// append nothing) — stale candidate rows never reach the cache.
#[test]
fn pending_candidates_never_survive_invalidation() {
    loom::model(|| {
        const SID: u64 = 7;
        const DIM: usize = 16;
        let shape = ModelShape::new(1, 2, DIM);
        let lanes = shape.lanes();

        let mut store = SessionStore::new();
        let now = Instant::now();
        let k = flat_chunk(0xA0, lanes, 3, DIM);
        let v = flat_chunk(0xB0, lanes, 3, DIM);
        store
            .open(SID, LatsConfig::default(), shape, &k, &v, 3, now)
            .expect("open session");
        // The op log shares the store's mutex so its order IS the order
        // the store observed.
        let shared = Arc::new(Mutex::new((store, Vec::<&'static str>::new())));

        // Thread A: fused verify block (stash 2 candidate rows), then
        // accept the first row in a separate critical section.
        let a = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let block = ModelStepBlock::new(
                    2,
                    rows(0xC0, 2 * lanes, DIM),
                    rows(0xC1, 2 * lanes, DIM),
                    rows(0xC2, 2 * lanes, DIM),
                );
                let mut scratch = BesfScratch::new();
                {
                    let mut g = shared.lock().expect("loom test lock");
                    let (store, log) = &mut *g;
                    store
                        .step_block(SID, &block, &mut scratch, 1, now)
                        .expect("verify block");
                    log.push("block");
                }
                thread::yield_now();
                let mut g = shared.lock().expect("loom test lock");
                let (store, log) = &mut *g;
                let got = store.accept(SID, 1, now);
                log.push("accept");
                got
            })
        };

        // Thread B: append-only step — the invalidating writer.
        let b = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let step = ModelStep::append_only(rows(0xD0, lanes, DIM), rows(0xD1, lanes, DIM));
                let mut scratch = BesfScratch::new();
                let mut g = shared.lock().expect("loom test lock");
                let (store, log) = &mut *g;
                store.step(SID, &step, &mut scratch, now).expect("append step");
                log.push("append");
            })
        };

        let accepted = a.join().expect("thread A");
        b.join().expect("thread B");

        let g = shared.lock().expect("loom test lock");
        let (store, log) = &*g;
        let block_at = log.iter().position(|&op| op == "block").expect("block ran");
        let accept_at = log.iter().position(|&op| op == "accept").expect("accept ran");
        let invalidated = log
            .iter()
            .position(|&op| op == "append")
            .is_some_and(|i| block_at < i && i < accept_at);

        if invalidated {
            // The append between stash and accept cleared the pending
            // rows: accept must fail typed, appending nothing.
            assert!(
                matches!(&accepted, Err(ServeError::ShapeMismatch { .. })),
                "accept after invalidation must fail typed, got {accepted:?}"
            );
        } else {
            assert!(accepted.is_ok(), "undisturbed accept must succeed: {accepted:?}");
        }
        // 3 prompt rows + 1 appended row + 1 row iff the accept landed —
        // a stale accept that appended anyway would show up here.
        let want = 3 + 1 + usize::from(accepted.is_ok());
        assert_eq!(store.context_len(SID), Some(want), "op log: {log:?}");
    });
}

/// Protocol 2: a store eviction racing client enqueues ends with the
/// router pin released, the scheduler drained, and every enqueued unit
/// either dispatched or failed typed — never silently lost.
#[test]
fn eviction_releases_pin_and_fails_queued_work_typed() {
    loom::model(|| {
        const SID: u64 = 1;
        const DIM: usize = 8;
        let shape = ModelShape::single(DIM);
        let now = Instant::now();

        let mut sched = Scheduler::new(SchedConfig::default(), 1);
        let mut router = Router::new(1);
        let (tx, rx) = std::sync::mpsc::channel::<SessionEvent>();
        sched
            .admit_open(SID, 0.6, shape, tx.clone(), &mut router)
            .expect("admit");
        let (pk, pv) = (rows(0xE0, 1, 4 * DIM).concat(), rows(0xE1, 1, 4 * DIM).concat());
        sched
            .enqueue_prefill(SID, ModelPrompt::single(DIM, 4, pk, pv), now)
            .expect("enqueue prefill");

        let shared = Arc::new(Mutex::new((sched, router, 0usize, 0usize)));

        // Thread A: the store evicted the session (idle TTL) — the
        // feedback must release the pin and fail queued work.
        let a = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let mut g = shared.lock().expect("loom test lock");
                let (sched, router, _, dropped) = &mut *g;
                *dropped += sched.on_feedback(
                    Feedback::Evicted { worker: 0, sessions: vec![(SID, EvictReason::IdleTtl)] },
                    router,
                );
            })
        };

        // Thread B: the client races one more step in, then drives a
        // dispatch round, acking each dispatch back as `Done`.
        let b = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let step_ok = {
                    let mut g = shared.lock().expect("loom test lock");
                    let (sched, _, _, _) = &mut *g;
                    let step = ModelStep::token(
                        rows(0xF0, 1, DIM),
                        rows(0xF1, 1, DIM),
                        rows(0xF2, 1, DIM),
                    );
                    sched.enqueue_step(SID, step, now).is_ok()
                };
                thread::yield_now();
                let mut g = shared.lock().expect("loom test lock");
                let (sched, router, dispatched, _) = &mut *g;
                for d in sched.plan_tick(router, now) {
                    *dispatched += 1;
                    sched.on_feedback(done(&d), router);
                }
                step_ok
            })
        };

        a.join().expect("thread A");
        let step_ok = b.join().expect("thread B");

        // Drain whatever is still runnable (bounded: the model enqueued
        // at most 2 units), then check the handoff invariants.
        let mut g = shared.lock().expect("loom test lock");
        let (sched, router, dispatched, dropped) = &mut *g;
        for _ in 0..8 {
            if !sched.busy() {
                break;
            }
            for d in sched.plan_tick(router, now) {
                *dispatched += 1;
                sched.on_feedback(done(&d), router);
            }
        }
        assert!(!sched.busy(), "scheduler must drain after eviction");
        assert_eq!(sched.n_sessions(), 0, "evicted session still tracked");
        assert_eq!(router.n_sessions(), 0, "router pin leaked past eviction");

        drop(tx);
        let events: Vec<SessionEvent> = rx.try_iter().collect();
        let evicted = events
            .iter()
            .filter(|e| matches!(e, SessionEvent::Evicted { .. }))
            .count();
        let errors = events
            .iter()
            .filter(|e| matches!(e, SessionEvent::Error(ServeError::UnknownSession { .. })))
            .count();
        assert_eq!(evicted, 1, "exactly one eviction notice: {events:?}");
        assert_eq!(errors, *dropped, "one typed error per dropped unit: {events:?}");
        // Conservation: the prefill plus the step (if it was accepted
        // into the queue) each either dispatched or failed typed.
        let enqueued = 1 + usize::from(step_ok);
        assert_eq!(
            *dispatched + *dropped,
            enqueued,
            "unit lost silently (dispatched {dispatched} + dropped {dropped} != {enqueued})"
        );
    });
}
