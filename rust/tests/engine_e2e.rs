//! Acceptance tests of the AttentionEngine refactor (DESIGN.md §3):
//!
//! 1. property: the coordinator's `BesfExecutor` output matches dense f32
//!    attention restricted to the kept tokens;
//! 2. a single-head `MultiHeadAttn` reproduces the legacy `QuantAttn`
//!    simulator report cycle-for-cycle;
//! 3. end-to-end: `BesfExecutor` driven through `Batcher`/`Router` with
//!    multi-head requests, with `kept` equal to `besf_select` survivors.

use bitstopper::attention::{attention_f32, rel_err};
use bitstopper::config::{Features, LatsConfig, SimConfig};
use bitstopper::coordinator::{AttnExecutor, AttnRequest, BatchConfig, BesfExecutor, EngineBuilder};
use bitstopper::engine::{HeadContext, SelectionPolicy};
use bitstopper::runtime::ArtifactKind;
use bitstopper::sim::{simulate_attention, simulate_multi_head};
use bitstopper::util::SplitMix64;
use bitstopper::workload::{head_seed, AttnWorkload, MultiHeadAttn, QuantAttn, SynthConfig};
use std::time::Duration;

fn gaussian_request(seq: usize, dim: usize, alpha: f64, seed: u64) -> AttnRequest {
    let mut rng = SplitMix64::new(seed);
    AttnRequest {
        id: 0,
        kind: ArtifactKind::BitStopper,
        alpha,
        seq,
        dim,
        q: (0..dim).map(|_| rng.normal() as f32).collect(),
        k: (0..seq * dim).map(|_| rng.normal() as f32).collect(),
        v: (0..seq * dim).map(|_| rng.normal() as f32).collect(),
        valid: vec![1.0; seq],
    }
}

/// Reproduce the executor's quantization + selection out-of-band.
fn reference_selection(req: &AttnRequest) -> Vec<usize> {
    let qa = QuantAttn::quantize(&[req.q.clone()], &req.k, &req.v, req.seq, req.dim);
    let head = HeadContext::new(&qa, LatsConfig { alpha: req.alpha, radius: 5.0 });
    head.select(0, SelectionPolicy::Lats).survivors
}

#[test]
fn prop_besf_executor_matches_dense_f32_on_kept_tokens() {
    // Property over seeds: on the tokens BESF keeps, the sparse INT12 output
    // must track a dense f32 attention computed over exactly those tokens.
    let (seq, dim) = (96usize, 32usize);
    for case in 0..12u64 {
        let req = gaussian_request(seq, dim, 0.6, 0x5EED + case);
        let mut exec = BesfExecutor::default();
        let (out, kept) = exec.execute(&req).expect("execute");

        let survivors = reference_selection(&req);
        assert_eq!(kept, survivors.len(), "case {case}: kept != besf survivors");
        assert!(kept >= 1, "case {case}: argmax must survive");

        // Dense f32 attention restricted to the kept tokens.
        let mut kg = Vec::with_capacity(kept * dim);
        let mut vg = Vec::with_capacity(kept * dim);
        for &j in &survivors {
            kg.extend_from_slice(&req.k[j * dim..(j + 1) * dim]);
            vg.extend_from_slice(&req.v[j * dim..(j + 1) * dim]);
        }
        let want = attention_f32(&req.q, &kg, &vg, kept, dim, dim);
        let err = rel_err(&out, &want);
        assert!(err < 0.05, "case {case}: INT12 sparse vs f32 sparse rel err {err}");
    }
}

#[test]
fn single_head_multihead_reproduces_legacy_sim_cycle_for_cycle() {
    for features in [Features::ALL, Features::BESF_BAP, Features::BESF_ONLY, Features::DENSE] {
        let mut cfg = SimConfig::default();
        cfg.features = features;
        let qa = QuantAttn::synth(192, 64, 3, 0xC1C);
        let mha = MultiHeadAttn::from_single(qa.clone());
        let legacy = simulate_attention(&qa, &cfg);
        let multi = simulate_multi_head(&mha, &cfg);
        assert_eq!(legacy.cycles, multi.cycles, "{features:?}: cycles");
        assert_eq!(legacy.qk_busy, multi.qk_busy, "{features:?}: qk_busy");
        assert_eq!(legacy.qk_span, multi.qk_span, "{features:?}: qk_span");
        assert_eq!(legacy.complexity, multi.complexity, "{features:?}: complexity");
        assert_eq!(legacy.queries, multi.queries);
        assert!((legacy.keep_rate - multi.keep_rate).abs() < 1e-15);
        assert!((legacy.utilization - multi.utilization).abs() < 1e-15);
        assert!((legacy.energy.total_pj() - multi.energy.total_pj()).abs() < 1e-9);
    }
}

#[test]
fn coordinator_e2e_besf_through_batcher_and_router() {
    // Multi-head requests (one request per head x query of a 3-head
    // workload) through the full coordinator: shape-grouped by the Batcher,
    // dispatched by the Router, executed sparsely by BesfExecutor. Every
    // response's `kept` must equal the besf_select survivor count for that
    // exact (head, query) problem.
    let (n_heads, queries, seq, dim, alpha) = (3usize, 4usize, 128usize, 32usize, 0.6f64);
    let mut requests: Vec<AttnRequest> = Vec::new();
    for h in 0..n_heads {
        let w = AttnWorkload::generate(SynthConfig::new(seq, dim, queries, head_seed(0xA11, h)));
        for qi in 0..queries {
            requests.push(AttnRequest {
                id: 0,
                kind: ArtifactKind::BitStopper,
                alpha,
                seq,
                dim,
                q: w.query(qi).to_vec(),
                k: w.k.clone(),
                v: w.v.clone(),
                valid: vec![1.0; seq],
            });
        }
    }
    let expected_kept: Vec<usize> =
        requests.iter().map(|r| reference_selection(r).len()).collect();

    let client = EngineBuilder::new()
        .workers(2)
        .batch(BatchConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
        .build()
        .expect("engine construction");
    let tickets: Vec<_> = requests
        .into_iter()
        .map(|r| client.submit(r).expect("submit"))
        .collect();
    let mut pruned_any = false;
    for (i, ticket) in tickets.into_iter().enumerate() {
        let resp = ticket.recv_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(resp.out.len(), dim);
        assert!(resp.out.iter().all(|x| x.is_finite()));
        assert_eq!(
            resp.kept, expected_kept[i],
            "request {i}: kept must equal besf_select survivors"
        );
        pruned_any |= resp.kept < seq;
    }
    assert!(pruned_any, "realistic workload must actually prune");

    let m = client.metrics();
    assert_eq!(m.completed, (n_heads * queries) as u64);
    assert_eq!(m.errors, 0);
    assert!(m.batches >= 1);
    client.shutdown();
}
