//! Typed client-surface acceptance (DESIGN.md §5): eviction observability,
//! RAII session handles, and the reject-at-capacity policy, end to end
//! through a real engine.
//!
//! The headline contract (the ROADMAP "eviction-aware clients" item): when a
//! worker store reclaims a live session, the client's `SessionHandle` stream
//! delivers `SessionEvent::Evicted { reason }` — TTL and LRU each with their
//! own reason — the next `step` on the handle fails typed with
//! `ServeError::UnknownSession`, and dropping a handle closes its session
//! and releases its router pin.
//!
//! With a spill tier configured (`EngineBuilder::spill_dir`, DESIGN.md §14)
//! the contract strengthens: capacity pressure demotes instead of evicting,
//! the handle sees a benign `SessionEvent::Demoted` and stays live, and the
//! engine serves several times the store capacity with zero
//! `UnknownSession` errors — the spill scenarios here pin that end to end
//! (and ride the CI TSan lane, exercising the worker ↔ batcher feedback
//! path under demote/promote churn).

use bitstopper::coordinator::{
    Client, EngineBuilder, EvictReason, Metrics, ModelPrompt, ModelStep, ModelStepBlock,
    ServeError, SessionEvent, SessionHandle,
};
use bitstopper::workload::ModelDecodeTrace;
use std::time::{Duration, Instant};

const ALPHA: f64 = 0.6;
const TIMEOUT: Duration = Duration::from_secs(10);

fn wait_metrics<F: Fn(&Metrics) -> bool>(client: &Client, pred: F) -> Metrics {
    let t0 = Instant::now();
    loop {
        let m = client.metrics();
        if pred(&m) || t0.elapsed() > Duration::from_secs(5) {
            return m;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn trace(seed: u64) -> ModelDecodeTrace {
    ModelDecodeTrace::synth(1, 1, 8, 2, 4, seed)
}

/// A unique per-test spill directory under the OS temp root.
fn spill_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("bitstopper-client-e2e-{}-{tag}", std::process::id()))
}

/// Fuse trace steps `first..first+rows` into one row-major verify block.
fn spec_block(mt: &ModelDecodeTrace, first: usize, rows: usize) -> ModelStepBlock {
    let (mut qs, mut ks, mut vs) = (Vec::new(), Vec::new(), Vec::new());
    for r in first..first + rows {
        let (q_r, k_r, v_r) = mt.step_rows(r);
        qs.extend(q_r);
        ks.extend(k_r);
        vs.extend(v_r);
    }
    ModelStepBlock::new(rows, qs, ks, vs)
}

fn open_trace(client: &Client, mt: &ModelDecodeTrace) -> SessionHandle {
    let mut h = client.open_model_session(ALPHA, mt.shape()).expect("open session");
    let (pk, pv) = mt.prompt();
    h.prefill(ModelPrompt { shape: mt.shape(), prompt_len: mt.prompt_len, k: pk, v: pv })
        .expect("queue prefill");
    assert_eq!(h.wait_prefilled(TIMEOUT).expect("prefill ack"), mt.prompt_len);
    h
}

#[test]
fn lru_eviction_is_observed_on_the_live_handles_stream() {
    // Capacity-1 store, no TTL: opening B evicts A by LRU. A's handle must
    // see Evicted { Capacity } — not silence — and its next step must fail
    // typed with UnknownSession, client-side, before touching the engine.
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .build()
        .expect("build");
    let mt = trace(0xE101);
    let mut a = open_trace(&client, &mt);
    let mut b = open_trace(&client, &mt);
    match a.recv_event_timeout(TIMEOUT).expect("eviction event") {
        SessionEvent::Evicted { reason } => assert_eq!(reason, EvictReason::Capacity),
        other => panic!("expected Evicted, got {other:?}"),
    }
    assert!(!a.is_live());
    let (qs, _, _) = mt.step_rows(0);
    assert_eq!(
        a.step(ModelStep::decode_only(qs.clone())).unwrap_err(),
        ServeError::UnknownSession { session: a.id() },
        "the next step after an observed eviction fails typed"
    );
    // B is untouched and still decodes.
    let (qs, ks, vs) = mt.step_rows(0);
    b.step(ModelStep::token(ks, vs, qs)).expect("B steps");
    let sr = b.wait_step(TIMEOUT).expect("B decodes");
    assert_eq!(sr.out().len(), mt.dim);
    let m = wait_metrics(&client, |m| m.evictions == 1 && m.session_pins == 1);
    assert_eq!(m.evictions, 1);
    assert_eq!(m.session_pins, 1, "evicted session's pin released, B's kept");
    client.shutdown();
}

#[test]
fn ttl_eviction_reports_its_own_reason() {
    // Capacity-1 store with a short TTL: by the time B opens, A has idled
    // past the TTL, so the sweep (not LRU) reclaims it — and the reason
    // says so.
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(Some(Duration::from_millis(50)))
        .build()
        .expect("build");
    let mt = trace(0xE102);
    let mut a = open_trace(&client, &mt);
    std::thread::sleep(Duration::from_millis(120));
    let _b = open_trace(&client, &mt);
    match a.recv_event_timeout(TIMEOUT).expect("eviction event") {
        SessionEvent::Evicted { reason } => assert_eq!(reason, EvictReason::IdleTtl),
        other => panic!("expected Evicted, got {other:?}"),
    }
    client.shutdown();
}

#[test]
fn unobserved_eviction_turns_the_in_flight_step_into_a_typed_error() {
    // The client races: it queues a step on A WITHOUT having read its event
    // stream, after B's open already evicted A engine-side. The stream must
    // deliver both the Evicted notice and the step's typed UnknownSession
    // error — never a silent hang. (Their relative order is not guaranteed:
    // a step dispatched before the eviction feedback drains fails on the
    // worker thread, racing the scheduler thread's Evicted send.)
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .build()
        .expect("build");
    let mt = trace(0xE103);
    let mut a = open_trace(&client, &mt);
    let _b = open_trace(&client, &mt);
    // A's handle has not observed the eviction yet: the submit is accepted
    // client-side and fails engine-side.
    let (qs, _, _) = mt.step_rows(0);
    a.step(ModelStep::decode_only(qs)).expect("submit races the eviction");
    let mut evicted = false;
    let mut step_error = false;
    for _ in 0..2 {
        match a.recv_event_timeout(TIMEOUT).expect("event") {
            SessionEvent::Evicted { reason } => {
                assert_eq!(reason, EvictReason::Capacity);
                evicted = true;
            }
            SessionEvent::Error(ServeError::UnknownSession { session }) => {
                assert_eq!(session, a.id());
                step_error = true;
            }
            other => panic!("expected Evicted or Error(UnknownSession), got {other:?}"),
        }
    }
    assert!(evicted, "the eviction itself must be delivered");
    assert!(step_error, "the raced step must fail typed, not vanish");
    client.shutdown();
}

#[test]
fn dropping_a_handle_closes_the_session_and_releases_its_pin() {
    let client = EngineBuilder::new().workers(2).build().expect("build");
    let mt = trace(0xE104);
    let keep = open_trace(&client, &mt);
    {
        let _dropped = open_trace(&client, &mt);
        let m = wait_metrics(&client, |m| m.session_pins == 2);
        assert_eq!(m.session_pins, 2);
        // `_dropped` goes out of scope here WITHOUT an explicit close.
    }
    let m = wait_metrics(&client, |m| m.session_pins == 1);
    assert_eq!(m.session_pins, 1, "RAII drop closed the session and released its pin");
    assert_eq!(m.errors, 0, "a drop-close is a normal close, not an error");
    drop(keep);
    let m = wait_metrics(&client, |m| m.session_pins == 0);
    assert_eq!(m.session_pins, 0);
    client.shutdown();
}

#[test]
fn reject_at_capacity_fails_the_new_open_and_keeps_the_live_session() {
    // The StoreAtCapacity policy: B's open is refused typed; A survives and
    // keeps decoding.
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .reject_at_capacity()
        .build()
        .expect("build");
    let mt = trace(0xE105);
    let mut a = open_trace(&client, &mt);
    let mut b = client.open_model_session(ALPHA, mt.shape()).expect("open B");
    let (pk, pv) = mt.prompt();
    b.prefill(ModelPrompt { shape: mt.shape(), prompt_len: mt.prompt_len, k: pk, v: pv })
        .expect("queue B prefill");
    assert_eq!(
        b.wait_prefilled(TIMEOUT).unwrap_err(),
        ServeError::StoreAtCapacity { capacity: 1 },
        "the refused open surfaces typed on B's stream"
    );
    let (qs, ks, vs) = mt.step_rows(0);
    a.step(ModelStep::token(ks, vs, qs)).expect("A steps");
    let sr = a.wait_step(TIMEOUT).expect("A still decodes");
    assert!(sr.kept_total() >= 1);
    let m = wait_metrics(&client, |m| m.session_pins == 1);
    assert_eq!(m.evictions, 0, "nothing was evicted");
    assert_eq!(m.session_pins, 1, "B's failed open released its pin, A's survives");
    client.shutdown();
}

#[test]
fn spill_serves_four_times_capacity_without_unknown_session() {
    // The ISSUE 9 acceptance scenario: a capacity-1 store with the spill
    // tier enabled serves FOUR live sessions. Capacity pressure demotes the
    // coldest session to disk instead of evicting it, and any unit arriving
    // for a demoted session promotes it back inside the worker's execute
    // path — so every stream completes every step, evictions stay at zero,
    // and no handle ever sees `UnknownSession`.
    let dir = spill_dir("4x");
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .spill_dir(&dir)
        .build()
        .expect("build");
    let mt = trace(0xE106);
    // 4x the hot-tier capacity: each open demotes the previous session.
    let mut handles: Vec<SessionHandle> = (0..4).map(|_| open_trace(&client, &mt)).collect();
    // Round-robin every stream through the full trace. Each step on a cold
    // session is a transparent demote-of-the-hot + promote-of-the-cold.
    for i in 0..mt.n_steps() {
        let (qs, ks, vs) = mt.step_rows(i);
        for h in handles.iter_mut() {
            h.step(ModelStep::token(ks.clone(), vs.clone(), qs.clone())).expect("queue step");
            let sr = h.wait_step(TIMEOUT).expect("a spilled session's step still completes");
            assert_eq!(sr.context_len, mt.prompt_len + i + 1);
            assert_eq!(sr.out().len(), mt.dim);
        }
    }
    let m = wait_metrics(&client, |m| m.demotions >= 3 && m.promotions >= 3);
    assert_eq!(m.errors, 0, "zero UnknownSession (or any other) errors");
    assert_eq!(m.evictions, 0, "demotion replaces eviction when the spill tier is on");
    assert!(m.demotions >= 3, "opening 4x capacity must demote, got {}", m.demotions);
    assert!(m.promotions >= 3, "every cold stream promoted back, got {}", m.promotions);
    assert_eq!(m.session_pins, 4, "all four sessions stay pinned, hot or spilled");
    // Demotions are visible on the handle streams as a benign notice — the
    // handle stays live. The notice is sent from the batcher thread on
    // feedback, racing the metrics update, so poll rather than assert once.
    let t0 = Instant::now();
    let mut saw_demoted = false;
    while !saw_demoted && t0.elapsed() < TIMEOUT {
        for h in handles.iter_mut() {
            while let Some(ev) = h.try_event() {
                if matches!(ev, SessionEvent::Demoted { .. }) {
                    saw_demoted = true;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(saw_demoted, "SessionEvent::Demoted must reach at least one handle");
    assert!(handles.iter().all(|h| h.is_live()), "a demoted handle is still live");
    drop(handles);
    client.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn demote_invalidates_the_pending_candidate_block_end_to_end() {
    // Speculative-decode interaction (DESIGN.md §14): a pending candidate
    // block (`step_many` without `accept`) is scratch state, NOT part of the
    // spill payload. Demoting the session drops it; the promoted session
    // must refuse a late `accept` typed — never resurrect candidate rows —
    // while plain decoding from the pre-block context keeps working.
    let dir = spill_dir("pending");
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .spill_dir(&dir)
        .build()
        .expect("build");
    let mt = trace(0xE107);
    let mut a = open_trace(&client, &mt);
    a.step_many(spec_block(&mt, 0, 2)).expect("queue verify block");
    let scored = a.wait_block(TIMEOUT).expect("block scored");
    assert_eq!(scored.q_rows, 2);
    // B's open demotes A while A's two candidate rows are still pending.
    let _b = open_trace(&client, &mt);
    // The accept promotes A back — but the candidates did not survive the
    // round trip, so it fails typed on A's stream (and A stays live).
    a.accept(1).expect("queue accept");
    match a.wait_accepted(TIMEOUT) {
        Err(ServeError::ShapeMismatch { what }) => {
            assert!(what.contains("0 pending"), "stale candidates gone, got: {what}")
        }
        other => panic!("expected ShapeMismatch on the stale accept, got {other:?}"),
    }
    assert!(a.is_live());
    // The restored context is the pre-block one: the next plain step lands
    // at prompt_len + 1, as if the candidate block never happened.
    let (qs, ks, vs) = mt.step_rows(0);
    a.step(ModelStep::token(ks, vs, qs)).expect("queue step");
    let sr = a.wait_step(TIMEOUT).expect("promoted session decodes");
    assert_eq!(sr.context_len, mt.prompt_len + 1);
    let m = wait_metrics(&client, |m| m.promotions >= 1);
    assert_eq!(m.evictions, 0);
    assert!(m.demotions >= 1 && m.promotions >= 1);
    client.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
