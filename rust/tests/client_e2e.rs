//! Typed client-surface acceptance (DESIGN.md §5): eviction observability,
//! RAII session handles, and the reject-at-capacity policy, end to end
//! through a real engine.
//!
//! The headline contract (the ROADMAP "eviction-aware clients" item): when a
//! worker store reclaims a live session, the client's `SessionHandle` stream
//! delivers `SessionEvent::Evicted { reason }` — TTL and LRU each with their
//! own reason — the next `step` on the handle fails typed with
//! `ServeError::UnknownSession`, and dropping a handle closes its session
//! and releases its router pin.

use bitstopper::coordinator::{
    Client, EngineBuilder, EvictReason, Metrics, ModelPrompt, ModelStep, ServeError, SessionEvent,
    SessionHandle,
};
use bitstopper::workload::ModelDecodeTrace;
use std::time::{Duration, Instant};

const ALPHA: f64 = 0.6;
const TIMEOUT: Duration = Duration::from_secs(10);

fn wait_metrics<F: Fn(&Metrics) -> bool>(client: &Client, pred: F) -> Metrics {
    let t0 = Instant::now();
    loop {
        let m = client.metrics();
        if pred(&m) || t0.elapsed() > Duration::from_secs(5) {
            return m;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn trace(seed: u64) -> ModelDecodeTrace {
    ModelDecodeTrace::synth(1, 1, 8, 2, 4, seed)
}

fn open_trace(client: &Client, mt: &ModelDecodeTrace) -> SessionHandle {
    let mut h = client.open_model_session(ALPHA, mt.shape()).expect("open session");
    let (pk, pv) = mt.prompt();
    h.prefill(ModelPrompt { shape: mt.shape(), prompt_len: mt.prompt_len, k: pk, v: pv })
        .expect("queue prefill");
    assert_eq!(h.wait_prefilled(TIMEOUT).expect("prefill ack"), mt.prompt_len);
    h
}

#[test]
fn lru_eviction_is_observed_on_the_live_handles_stream() {
    // Capacity-1 store, no TTL: opening B evicts A by LRU. A's handle must
    // see Evicted { Capacity } — not silence — and its next step must fail
    // typed with UnknownSession, client-side, before touching the engine.
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .build()
        .expect("build");
    let mt = trace(0xE101);
    let mut a = open_trace(&client, &mt);
    let mut b = open_trace(&client, &mt);
    match a.recv_event_timeout(TIMEOUT).expect("eviction event") {
        SessionEvent::Evicted { reason } => assert_eq!(reason, EvictReason::Capacity),
        other => panic!("expected Evicted, got {other:?}"),
    }
    assert!(!a.is_live());
    let (qs, _, _) = mt.step_rows(0);
    assert_eq!(
        a.step(ModelStep::decode_only(qs.clone())).unwrap_err(),
        ServeError::UnknownSession { session: a.id() },
        "the next step after an observed eviction fails typed"
    );
    // B is untouched and still decodes.
    let (qs, ks, vs) = mt.step_rows(0);
    b.step(ModelStep::token(ks, vs, qs)).expect("B steps");
    let sr = b.wait_step(TIMEOUT).expect("B decodes");
    assert_eq!(sr.out().len(), mt.dim);
    let m = wait_metrics(&client, |m| m.evictions == 1 && m.session_pins == 1);
    assert_eq!(m.evictions, 1);
    assert_eq!(m.session_pins, 1, "evicted session's pin released, B's kept");
    client.shutdown();
}

#[test]
fn ttl_eviction_reports_its_own_reason() {
    // Capacity-1 store with a short TTL: by the time B opens, A has idled
    // past the TTL, so the sweep (not LRU) reclaims it — and the reason
    // says so.
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(Some(Duration::from_millis(50)))
        .build()
        .expect("build");
    let mt = trace(0xE102);
    let mut a = open_trace(&client, &mt);
    std::thread::sleep(Duration::from_millis(120));
    let _b = open_trace(&client, &mt);
    match a.recv_event_timeout(TIMEOUT).expect("eviction event") {
        SessionEvent::Evicted { reason } => assert_eq!(reason, EvictReason::IdleTtl),
        other => panic!("expected Evicted, got {other:?}"),
    }
    client.shutdown();
}

#[test]
fn unobserved_eviction_turns_the_in_flight_step_into_a_typed_error() {
    // The client races: it queues a step on A WITHOUT having read its event
    // stream, after B's open already evicted A engine-side. The stream must
    // deliver both the Evicted notice and the step's typed UnknownSession
    // error — never a silent hang. (Their relative order is not guaranteed:
    // a step dispatched before the eviction feedback drains fails on the
    // worker thread, racing the scheduler thread's Evicted send.)
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .build()
        .expect("build");
    let mt = trace(0xE103);
    let mut a = open_trace(&client, &mt);
    let _b = open_trace(&client, &mt);
    // A's handle has not observed the eviction yet: the submit is accepted
    // client-side and fails engine-side.
    let (qs, _, _) = mt.step_rows(0);
    a.step(ModelStep::decode_only(qs)).expect("submit races the eviction");
    let mut evicted = false;
    let mut step_error = false;
    for _ in 0..2 {
        match a.recv_event_timeout(TIMEOUT).expect("event") {
            SessionEvent::Evicted { reason } => {
                assert_eq!(reason, EvictReason::Capacity);
                evicted = true;
            }
            SessionEvent::Error(ServeError::UnknownSession { session }) => {
                assert_eq!(session, a.id());
                step_error = true;
            }
            other => panic!("expected Evicted or Error(UnknownSession), got {other:?}"),
        }
    }
    assert!(evicted, "the eviction itself must be delivered");
    assert!(step_error, "the raced step must fail typed, not vanish");
    client.shutdown();
}

#[test]
fn dropping_a_handle_closes_the_session_and_releases_its_pin() {
    let client = EngineBuilder::new().workers(2).build().expect("build");
    let mt = trace(0xE104);
    let keep = open_trace(&client, &mt);
    {
        let _dropped = open_trace(&client, &mt);
        let m = wait_metrics(&client, |m| m.session_pins == 2);
        assert_eq!(m.session_pins, 2);
        // `_dropped` goes out of scope here WITHOUT an explicit close.
    }
    let m = wait_metrics(&client, |m| m.session_pins == 1);
    assert_eq!(m.session_pins, 1, "RAII drop closed the session and released its pin");
    assert_eq!(m.errors, 0, "a drop-close is a normal close, not an error");
    drop(keep);
    let m = wait_metrics(&client, |m| m.session_pins == 0);
    assert_eq!(m.session_pins, 0);
    client.shutdown();
}

#[test]
fn reject_at_capacity_fails_the_new_open_and_keeps_the_live_session() {
    // The StoreAtCapacity policy: B's open is refused typed; A survives and
    // keeps decoding.
    let client = EngineBuilder::new()
        .workers(1)
        .session_capacity(1)
        .idle_ttl(None)
        .reject_at_capacity()
        .build()
        .expect("build");
    let mt = trace(0xE105);
    let mut a = open_trace(&client, &mt);
    let mut b = client.open_model_session(ALPHA, mt.shape()).expect("open B");
    let (pk, pv) = mt.prompt();
    b.prefill(ModelPrompt { shape: mt.shape(), prompt_len: mt.prompt_len, k: pk, v: pv })
        .expect("queue B prefill");
    assert_eq!(
        b.wait_prefilled(TIMEOUT).unwrap_err(),
        ServeError::StoreAtCapacity { capacity: 1 },
        "the refused open surfaces typed on B's stream"
    );
    let (qs, ks, vs) = mt.step_rows(0);
    a.step(ModelStep::token(ks, vs, qs)).expect("A steps");
    let sr = a.wait_step(TIMEOUT).expect("A still decodes");
    assert!(sr.kept_total() >= 1);
    let m = wait_metrics(&client, |m| m.session_pins == 1);
    assert_eq!(m.evictions, 0, "nothing was evicted");
    assert_eq!(m.session_pins, 1, "B's failed open released its pin, A's survives");
    client.shutdown();
}
