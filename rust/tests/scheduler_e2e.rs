//! Continuous-batching scheduler acceptance (DESIGN.md §9), driven entirely
//! through the typed client surface (`EngineBuilder`/`Client`/
//! `SessionHandle`, DESIGN.md §5):
//!
//! 1. **Bit-identity** — multi-layer/multi-head decode steps batched across
//!    sessions by the scheduler (chunked prefill, 3 workers, a mid-stream
//!    session close) are bit-identical, lane for lane, to the same traces
//!    decoded sequentially as one-shot requests over the grown contexts.
//! 2. **Liveness under prefill pressure** — with a long chunked prefill in
//!    flight, concurrent decode sessions all make progress (the strict
//!    K-tick starvation bound is unit-tested deterministically in
//!    `coordinator::scheduler`).
//! 3. **Backpressure** — a saturated worker defers surplus runnable
//!    sessions instead of over-dispatching, and everything still completes.

use bitstopper::coordinator::{
    AttnRequest, Client, EngineBuilder, Metrics, ModelPrompt, ModelStep, SessionHandle,
};
use bitstopper::runtime::ArtifactKind;
use bitstopper::workload::ModelDecodeTrace;
use std::time::{Duration, Instant};

const ALPHA: f64 = 0.6;
const TIMEOUT: Duration = Duration::from_secs(10);

/// Scheduler gauges are published asynchronously by the coordinator thread
/// (a client ack can arrive a few statements before the matching publish):
/// poll until `pred` holds or a 5 s deadline passes, then return the last
/// snapshot for the hard asserts.
fn wait_metrics<F: Fn(&Metrics) -> bool>(client: &Client, pred: F) -> Metrics {
    let t0 = Instant::now();
    loop {
        let m = client.metrics();
        if pred(&m) || t0.elapsed() > Duration::from_secs(5) {
            return m;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn open_trace(client: &Client, mt: &ModelDecodeTrace) -> SessionHandle {
    let mut h = client.open_model_session(ALPHA, mt.shape()).expect("open session");
    let (pk, pv) = mt.prompt();
    h.prefill(ModelPrompt { shape: mt.shape(), prompt_len: mt.prompt_len, k: pk, v: pv })
        .expect("queue prefill");
    h
}

#[test]
fn batched_multi_layer_decode_is_bit_identical_to_sequential_one_shot() {
    let n_sessions = 3usize;
    let steps = 6usize;
    let traces: Vec<ModelDecodeTrace> = (0..n_sessions)
        .map(|s| ModelDecodeTrace::synth(2, 2, 24, steps, 16, 0xA110 + s as u64))
        .collect();
    // 3 workers; prefill chunked at 8 rows so every prompt takes 3 ticks.
    let client = EngineBuilder::new()
        .workers(3)
        .prefill_chunk(8)
        .max_inflight_per_worker(2)
        .build()
        .expect("build");
    let mut handles: Vec<SessionHandle> = traces.iter().map(|mt| open_trace(&client, mt)).collect();
    for (s, h) in handles.iter_mut().enumerate() {
        assert_eq!(h.wait_prefilled(TIMEOUT).expect("prefill ack"), traces[s].prompt_len);
    }

    // Session 1 closes mid-stream after this many steps; the others run on.
    let close_after = 2usize;
    let mut live: Vec<usize> = (0..n_sessions).collect();
    for i in 0..steps {
        if i == close_after {
            let closed = 1usize;
            handles[closed].close().expect("mid-stream close");
            handles[closed].wait_closed(TIMEOUT).expect("mid-stream close ack");
            live.retain(|&s| s != closed);
        }
        // Enqueue step i for every live session BEFORE receiving any of
        // them: the scheduler batches them into shared ticks across the 3
        // workers (continuous batching), not one session at a time.
        for &s in &live {
            let (qs, ks, vs) = traces[s].step_rows(i);
            handles[s].step(ModelStep::token(ks, vs, qs)).expect("queue step");
        }
        for &s in &live {
            let got = handles[s].wait_step(TIMEOUT).expect("batched decode step");
            assert_eq!(got.context_len, traces[s].prompt_len + i + 1);
            assert_eq!(got.outs.len(), traces[s].n_lanes());
            // Sequential one-shot reference: each lane as an independent
            // BitStopper request over the same grown context.
            for (l, lane) in traces[s].lanes.iter().enumerate() {
                let (k_full, v_full, n) = lane.context_after(i + 1);
                let one_shot = client
                    .submit_blocking(AttnRequest {
                        id: 0,
                        kind: ArtifactKind::BitStopper,
                        alpha: ALPHA,
                        seq: n,
                        dim: traces[s].dim,
                        q: lane.steps[i].q.clone(),
                        k: k_full,
                        v: v_full,
                        valid: vec![1.0; n],
                    })
                    .expect("one-shot reference");
                assert_eq!(
                    got.outs[l], one_shot.out,
                    "session {s} step {i} lane {l}: batched != sequential"
                );
                assert_eq!(
                    got.kept[l], one_shot.kept,
                    "session {s} step {i} lane {l}: survivor counts"
                );
                assert!(got.kept[l] >= 1);
            }
        }
    }
    for &s in &live {
        handles[s].close().expect("close");
        handles[s].wait_closed(TIMEOUT).expect("close ack");
    }
    let want_steps = n_sessions * close_after + live.len() * (steps - close_after);
    let m = wait_metrics(&client, |m| {
        m.model_steps as usize == want_steps
            && m.prefill_chunks as usize == n_sessions * 3
            && m.session_pins == 0
            && m.decode_keep_rate > 0.0
    });
    assert_eq!(m.errors, 0, "sticky pinning must hold across 3 workers");
    assert_eq!(m.model_steps as usize, want_steps);
    assert_eq!(m.prefill_chunks as usize, n_sessions * 3, "24-row prompts in 8-row chunks");
    assert_eq!(m.session_pins, 0, "all pins released after closes");
    assert!(m.decode_keep_rate > 0.0 && m.decode_keep_rate <= 1.0);
    client.shutdown();
}

#[test]
fn decode_sessions_progress_while_long_prefill_is_admitted() {
    // A 64-row prompt admitted in 4-row chunks (16 ticks) shares the engine
    // with 3 decode sessions. Every decode session must keep streaming
    // tokens while the prefill is in flight — chunked admission means the
    // prefill never monopolizes a tick.
    let client = EngineBuilder::new()
        .workers(2)
        .prefill_chunk(4)
        .max_inflight_per_worker(2)
        .build()
        .expect("build");
    let long = ModelDecodeTrace::synth(1, 1, 64, 1, 8, 0xFA17);
    let shorts: Vec<ModelDecodeTrace> =
        (0..3).map(|s| ModelDecodeTrace::synth(1, 1, 4, 8, 8, 0xFA20 + s as u64)).collect();

    // Admit and finish the short prompts first, then start the long prefill
    // and immediately queue every decode step behind it.
    let mut handles: Vec<SessionHandle> = shorts.iter().map(|mt| open_trace(&client, mt)).collect();
    for h in handles.iter_mut() {
        h.wait_prefilled(TIMEOUT).expect("short prefill ack");
    }
    let mut long_h = open_trace(&client, &long);
    for (s, mt) in shorts.iter().enumerate() {
        for i in 0..mt.n_steps() {
            let (qs, ks, vs) = mt.step_rows(i);
            handles[s].step(ModelStep::token(ks, vs, qs)).expect("queue step");
        }
    }
    // All 24 decode steps complete even though a 16-chunk prefill is being
    // admitted concurrently.
    for (s, mt) in shorts.iter().enumerate() {
        for _ in 0..mt.n_steps() {
            let r = handles[s].wait_step(TIMEOUT).expect("decode step under prefill pressure");
            assert!(r.kept_total() >= 1);
        }
    }
    assert_eq!(long_h.wait_prefilled(TIMEOUT).expect("long prefill ack"), 64);
    let (qs, ks, vs) = long.step_rows(0);
    long_h.step(ModelStep::token(ks, vs, qs)).expect("queue long step");
    let r = long_h.wait_step(TIMEOUT).expect("long session decodes after its prefill");
    assert_eq!(r.context_len, 65);
    let m = wait_metrics(&client, |m| m.prefill_chunks as usize == 3 + 16);
    assert_eq!(m.errors, 0);
    assert_eq!(m.prefill_chunks as usize, 3 + 16, "long prompt admitted in 16 chunks");
    assert!(m.ticks >= 16, "chunked prefill spread over at least 16 ticks");
    client.shutdown();
}

#[test]
fn saturated_worker_defers_instead_of_overdispatching() {
    // One worker with an in-flight cap of 1 and three sessions with queued
    // steps: at any tick at least two sessions compete for the single slot,
    // so the scheduler must record deferrals — and still finish everything.
    let client = EngineBuilder::new()
        .workers(1)
        .prefill_chunk(64)
        .max_inflight_per_worker(1)
        .build()
        .expect("build");
    let traces: Vec<ModelDecodeTrace> =
        (0..3).map(|s| ModelDecodeTrace::synth(1, 1, 8, 4, 8, 0xBB00 + s as u64)).collect();
    let mut handles: Vec<SessionHandle> = traces.iter().map(|mt| open_trace(&client, mt)).collect();
    for h in handles.iter_mut() {
        h.wait_prefilled(TIMEOUT).expect("prefill ack");
    }
    for (s, mt) in traces.iter().enumerate() {
        for i in 0..mt.n_steps() {
            let (qs, ks, vs) = mt.step_rows(i);
            handles[s].step(ModelStep::token(ks, vs, qs)).expect("queue step");
        }
    }
    for (s, mt) in traces.iter().enumerate() {
        for i in 0..mt.n_steps() {
            let r = handles[s].wait_step(TIMEOUT).expect("step under backpressure");
            assert_eq!(r.context_len, mt.prompt_len + i + 1, "session {s} step {i}");
        }
    }
    let m = wait_metrics(&client, |m| m.model_steps == 12 && m.deferred >= 1);
    assert_eq!(m.errors, 0);
    assert_eq!(m.model_steps, 12);
    assert!(m.deferred >= 1, "capacity-1 worker with 3 runnable sessions must defer");
    client.shutdown();
}
