//! Golden cross-layer test: the Rust BESF/LATS functional model must produce
//! *identical* selections to the Python oracle (`kernels/ref.py`) on the
//! vectors exported by `train_tiny.py` — quantized real attention traces plus
//! adversarial random cases.
//!
//! File format (artifacts/tiny_model/golden_besf.txt):
//! ```text
//! <n_cases>
//! case <dim> <seq> <alpha> <radius_int>
//! <q ints ...>
//! <k row 0 ints ...>      (seq rows)
//! <death rounds ...>      (seq entries; 12 = survived)
//! <survivor indices ...>  (may be empty line)
//! ```

use bitstopper::algo::besf::{besf_select, SURVIVED};
use bitstopper::algo::Lats;
use bitstopper::quant::{margin::BitMargins, BitPlanes, IntMatrix};

struct GoldenCase {
    dim: usize,
    seq: usize,
    alpha: f64,
    radius_int: i64,
    q: Vec<i16>,
    k: IntMatrix,
    death: Vec<u8>,
    survivors: Vec<usize>,
}

fn golden_path() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/tiny_model/golden_besf.txt")
}

fn parse_golden(text: &str) -> Vec<GoldenCase> {
    let mut lines = text.lines();
    let n: usize = lines.next().expect("count line").trim().parse().expect("count");
    let mut cases = Vec::with_capacity(n);
    for _ in 0..n {
        let header = lines.next().expect("case header");
        let mut h = header.split_whitespace();
        assert_eq!(h.next(), Some("case"));
        let dim: usize = h.next().unwrap().parse().unwrap();
        let seq: usize = h.next().unwrap().parse().unwrap();
        let alpha: f64 = h.next().unwrap().parse().unwrap();
        let radius_int: i64 = h.next().unwrap().parse().unwrap();
        let ints = |line: &str| -> Vec<i64> {
            line.split_whitespace().map(|t| t.parse().unwrap()).collect()
        };
        let q: Vec<i16> = ints(lines.next().unwrap()).into_iter().map(|v| v as i16).collect();
        assert_eq!(q.len(), dim);
        let mut kdata = Vec::with_capacity(seq * dim);
        for _ in 0..seq {
            let row = ints(lines.next().unwrap());
            assert_eq!(row.len(), dim);
            kdata.extend(row.into_iter().map(|v| v as i16));
        }
        let death: Vec<u8> = ints(lines.next().unwrap()).into_iter().map(|v| v as u8).collect();
        assert_eq!(death.len(), seq);
        let survivors: Vec<usize> = lines
            .next()
            .unwrap()
            .split_whitespace()
            .map(|t| t.parse().unwrap())
            .collect();
        cases.push(GoldenCase {
            dim,
            seq,
            alpha,
            radius_int,
            q,
            k: IntMatrix::new(seq, dim, kdata),
            death,
            survivors,
        });
    }
    cases
}

fn load_cases() -> Option<Vec<GoldenCase>> {
    let path = golden_path();
    if !path.exists() {
        eprintln!("SKIP: {} missing — run `make artifacts`", path.display());
        return None;
    }
    Some(parse_golden(&std::fs::read_to_string(path).unwrap()))
}

#[test]
fn rust_besf_matches_python_oracle_survivors() {
    let Some(cases) = load_cases() else { return };
    assert!(cases.len() >= 4, "expected several golden cases");
    for (i, c) in cases.iter().enumerate() {
        let planes = BitPlanes::decompose(&c.k);
        let margins = BitMargins::generate(&c.q);
        let lats = Lats::from_int(c.alpha, c.radius_int);
        let got = besf_select(&c.q, &planes, &margins, &lats);
        assert_eq!(
            got.survivors, c.survivors,
            "case {i} (dim {} seq {} alpha {}): survivor mismatch",
            c.dim, c.seq, c.alpha
        );
    }
}

#[test]
fn rust_besf_matches_python_oracle_death_rounds() {
    let Some(cases) = load_cases() else { return };
    for (i, c) in cases.iter().enumerate() {
        let planes = BitPlanes::decompose(&c.k);
        let margins = BitMargins::generate(&c.q);
        let lats = Lats::from_int(c.alpha, c.radius_int);
        let got = besf_select(&c.q, &planes, &margins, &lats);
        let got_death: Vec<u8> = got.death_round.clone();
        assert_eq!(got_death, c.death, "case {i}: death-round mismatch");
        // Internal consistency: survivors are exactly death == SURVIVED.
        let from_death: Vec<usize> = got_death
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == SURVIVED)
            .map(|(j, _)| j)
            .collect();
        assert_eq!(from_death, got.survivors);
    }
}

#[test]
fn golden_cases_cover_real_traces_and_random() {
    let Some(cases) = load_cases() else { return };
    // Later cases are random 32-key adversarial cases; earlier ones come
    // from real tiny-model traces (seq = the model's context window).
    assert!(cases.iter().any(|c| c.seq == 32));
    assert!(cases.iter().any(|c| c.seq != 32), "expected real-trace cases too");
    // Alpha range must include aggressive and permissive ends.
    let alphas: Vec<f64> = cases.iter().map(|c| c.alpha).collect();
    assert!(alphas.iter().any(|&a| a <= 0.21));
    assert!(alphas.iter().any(|&a| a >= 0.79));
}
