"""Layer-1 kernel correctness: Pallas kernels vs the pure-numpy oracle.

Hypothesis sweeps shapes and value ranges; every property asserts
allclose against ref.py — the core correctness signal of the build path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitplane_qk, ref, sparse_attn

RNG = np.random.RandomState(0)


def rand_ints(shape, rng):
    return rng.randint(-2048, 2048, size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# bitplane decomposition / margins (oracle self-consistency)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=-2048, max_value=2047))
@settings(max_examples=60, deadline=None)
def test_planes_reconstruct_every_value(v):
    planes = ref.decompose_planes(np.array([[v]], np.float32))
    w = ref.plane_weights()
    total = float((w[:, None, None] * planes).sum())
    assert total == v


@given(st.integers(min_value=1, max_value=48), st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_margin_interval_sound(dim, seed):
    rng = np.random.RandomState(seed % (2**31))
    q = rand_ints(dim, rng)
    k = rand_ints((1, dim), rng)
    planes = ref.decompose_planes(k)
    scores = ref.ref_cumulative_scores(q, planes)[:, 0]
    m_min, m_max = ref.ref_margins(q)
    exact = float(np.asarray(k, np.float64)[0] @ np.asarray(q, np.float64))
    for r in range(ref.N_BITS):
        assert scores[r] + m_min[r] <= exact + 1e-6
        assert scores[r] + m_max[r] >= exact - 1e-6
    assert scores[ref.N_BITS - 1] == pytest.approx(exact)


# ---------------------------------------------------------------------------
# Pallas bitplane_scores vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seq,dim", [(8, 8), (64, 32), (100, 17), (128, 64)])
def test_bitplane_scores_matches_ref(seq, dim):
    rng = np.random.RandomState(seq * 1000 + dim)
    q = rand_ints(dim, rng)
    k = rand_ints((seq, dim), rng)
    planes = ref.decompose_planes(k)
    got = np.asarray(bitplane_qk.bitplane_scores(q, planes))
    want = ref.ref_bitplane_scores(q, planes)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@given(
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=1, max_value=24),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_bitplane_scores_matches_ref_hypothesis(seq, dim, seed):
    rng = np.random.RandomState(seed)
    q = rand_ints(dim, rng)
    k = rand_ints((seq, dim), rng)
    planes = ref.decompose_planes(k)
    got = np.asarray(bitplane_qk.bitplane_scores(q, planes, block_seq=16))
    want = ref.ref_bitplane_scores(q, planes)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_cumulative_scores_equal_exact_dot_at_lsb():
    rng = np.random.RandomState(3)
    q = rand_ints(24, rng)
    k = rand_ints((16, 24), rng)
    planes = ref.decompose_planes(k)
    cum = np.asarray(bitplane_qk.cumulative_scores(q, planes))
    exact = np.asarray(k, np.float64) @ np.asarray(q, np.float64)
    np.testing.assert_allclose(cum[-1], exact, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Pallas masked attention vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seq,dim", [(4, 4), (64, 32), (128, 64)])
def test_masked_attention_matches_ref(seq, dim):
    rng = np.random.RandomState(seq + dim)
    logits = rng.normal(0, 2, size=seq).astype(np.float32)
    mask = (rng.rand(seq) < 0.5).astype(np.float32)
    mask[int(np.argmax(logits))] = 1.0  # never empty
    v = rng.normal(0, 1, size=(seq, dim)).astype(np.float32)
    got = np.asarray(sparse_attn.masked_attention(logits, mask, v))
    want = ref.ref_masked_attention(logits, mask, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_masked_attention_full_mask_is_softmax():
    rng = np.random.RandomState(9)
    seq, dim = 32, 16
    logits = rng.normal(size=seq).astype(np.float32)
    v = rng.normal(size=(seq, dim)).astype(np.float32)
    got = np.asarray(sparse_attn.masked_attention(logits, np.ones(seq, np.float32), v))
    e = np.exp(logits - logits.max())
    p = e / e.sum()
    np.testing.assert_allclose(got, p @ v, rtol=2e-5, atol=2e-5)


def test_masked_attention_pruned_tokens_have_zero_weight():
    seq, dim = 8, 4
    logits = np.zeros(seq, np.float32)
    mask = np.zeros(seq, np.float32)
    mask[3] = 1.0
    v = np.arange(seq * dim, dtype=np.float32).reshape(seq, dim)
    got = np.asarray(sparse_attn.masked_attention(logits, mask, v))
    np.testing.assert_allclose(got, v[3], rtol=1e-6)


# ---------------------------------------------------------------------------
# BESF selection oracle properties (mirrors the Rust proptests)
# ---------------------------------------------------------------------------

@given(
    st.integers(min_value=2, max_value=40),
    st.integers(min_value=1, max_value=20),
    st.floats(min_value=0.0, max_value=1.0),
    st.integers(min_value=1, max_value=10**6),
    st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_besf_matches_brute_force(seq, dim, alpha, radius, seed):
    rng = np.random.RandomState(seed)
    q = rand_ints(dim, rng)
    k = rand_ints((seq, dim), rng)
    _, surv, _ = ref.ref_besf_select(q, k, alpha, radius)
    brute = ref.ref_brute_force_select(q, k, alpha, radius)
    np.testing.assert_array_equal(surv, brute)


def test_besf_argmax_always_survives():
    rng = np.random.RandomState(17)
    for _ in range(10):
        q = rand_ints(16, rng)
        k = rand_ints((32, 16), rng)
        _, surv, exact = ref.ref_besf_select(q, k, 0.0, 1)
        assert surv[int(np.argmax(exact))]


def test_besf_death_rounds_monotone_with_alpha():
    rng = np.random.RandomState(23)
    q = rand_ints(32, rng)
    k = rand_ints((64, 32), rng)
    d_tight, s_tight, _ = ref.ref_besf_select(q, k, 0.1, 10**5)
    d_loose, s_loose, _ = ref.ref_besf_select(q, k, 0.9, 10**5)
    # Looser band keeps at least as many tokens at least as long.
    assert s_tight.sum() <= s_loose.sum()
    assert np.all(d_tight <= d_loose)


def test_quantize_roundtrip_error():
    rng = np.random.RandomState(31)
    x = rng.normal(0, 3, size=256).astype(np.float32)
    q, s = ref.quantize_sym(x)
    assert np.all(np.abs(x - q * s) <= 0.5 * s + 1e-6)
    assert np.abs(q).max() <= 2048
