"""AOT export smoke tests: lowering must produce parseable HLO text whose
execution under jax matches the eager pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_dense_lowering_produces_hlo_text():
    lowered = aot.lower_dense(32, 16)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 4 parameters: q, k, v, valid.
    assert text.count("parameter(") >= 4


def test_bitstopper_lowering_produces_hlo_text():
    lowered = aot.lower_bitstopper(32, 16, 0.6)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lowered_dense_executes_and_matches_eager():
    seq, dim = 32, 16
    rng = np.random.RandomState(0)
    q = rng.normal(size=dim).astype(np.float32)
    k = rng.normal(size=(seq, dim)).astype(np.float32)
    v = rng.normal(size=(seq, dim)).astype(np.float32)
    valid = np.ones(seq, np.float32)
    compiled = aot.lower_dense(seq, dim).compile()
    out_c, mask_c = compiled(q, k, v, valid)
    out_e, mask_e = model.dense_attention(jnp.asarray(q), jnp.asarray(k),
                                          jnp.asarray(v), valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_e),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(mask_c), np.asarray(mask_e))


def test_lowered_bitstopper_executes_and_matches_eager():
    seq, dim = 32, 16
    rng = np.random.RandomState(1)
    q = rng.normal(size=dim).astype(np.float32)
    k = rng.normal(size=(seq, dim)).astype(np.float32)
    v = rng.normal(size=(seq, dim)).astype(np.float32)
    valid = np.ones(seq, np.float32)
    compiled = aot.lower_bitstopper(seq, dim, 0.5).compile()
    out_c, mask_c = compiled(q, k, v, valid)
    out_e, mask_e = model.besf_attention(jnp.asarray(q), jnp.asarray(k),
                                         jnp.asarray(v), alpha=0.5,
                                         valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_e),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(mask_c), np.asarray(mask_e))


def test_export_quick_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.export(out, shapes=[(16, 8)], alphas=[0.6])
    files = os.listdir(out)
    assert "manifest.txt" in files
    assert any(f.startswith("attn_dense_16x8") for f in files)
    assert any(f.startswith("attn_bitstopper_16x8") for f in files)
    manifest = open(os.path.join(out, "manifest.txt")).read()
    assert "kind=dense" in manifest and "kind=bitstopper" in manifest
    for line in manifest.strip().splitlines():
        fname = line.split()[0]
        text = open(os.path.join(out, fname)).read()
        assert "HloModule" in text
