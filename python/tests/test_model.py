"""Layer-2 model tests: fused BESF attention pipeline + tiny transformer."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def synth(seq, dim, seed):
    rng = np.random.RandomState(seed)
    q = rng.normal(0, 1, size=dim).astype(np.float32)
    k = rng.normal(0, 1, size=(seq, dim)).astype(np.float32)
    v = rng.normal(0, 1, size=(seq, dim)).astype(np.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# Fused BESF attention
# ---------------------------------------------------------------------------

def test_besf_attention_close_to_int12_dense_at_default_alpha():
    q, k, v = synth(128, 32, 1)
    out, mask = model.besf_attention(q, k, v, alpha=0.6)
    want = ref.ref_int12_attention(q, k, v)
    got = np.asarray(out)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.15, f"rel err {rel} (mask keeps {np.asarray(mask).sum()})"


def test_besf_attention_huge_radius_equals_dense():
    q, k, v = synth(64, 16, 2)
    out_s, mask = model.besf_attention(q, k, v, alpha=1.0, radius_logit=1e6)
    out_d, _ = model.dense_attention(q, k, v)
    assert np.asarray(mask).sum() == 64
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)


def test_besf_mask_matches_oracle_selection():
    q, k, v = synth(96, 24, 3)
    alpha = 0.5
    q_int, qs = ref.quantize_sym(q)
    k_int, ks = ref.quantize_sym(k)
    radius_int = ref.radius_int_from_logit(5.0, 24, qs, ks)
    _, want_mask, _ = ref.ref_besf_select(q_int, k_int, alpha, radius_int)
    _, got_mask = model.besf_attention(q, k, v, alpha=alpha)
    np.testing.assert_array_equal(np.asarray(got_mask) > 0, want_mask)


def test_besf_attention_prunes_at_tight_alpha():
    q, k, v = synth(256, 32, 4)
    _, mask = model.besf_attention(q, k, v, alpha=0.2)
    kept = float(np.asarray(mask).sum())
    assert kept < 256, "tight alpha must prune"
    assert kept >= 1, "max token always survives"


def test_valid_mask_excludes_padding():
    q, k, v = synth(32, 16, 5)
    # Give padding rows large values so they would otherwise dominate.
    k[16:] = 10.0
    valid = np.zeros(32, np.float32)
    valid[:16] = 1.0
    _, mask = model.besf_attention(q, k, v, valid=valid)
    assert np.asarray(mask)[16:].sum() == 0


def test_dense_attention_matches_ref_int12():
    # The in-graph path keeps V at f32 (the V-PU dequantizes on the fly), the
    # oracle quantizes V too — differences are bounded by V's quant error.
    q, k, v = synth(64, 32, 6)
    out, _ = model.dense_attention(q, k, v)
    want = ref.ref_int12_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Tiny transformer
# ---------------------------------------------------------------------------

CFG = {"vocab": 19, "d_model": 16, "n_layers": 2, "n_heads": 2, "max_seq": 12}


def test_tiny_forward_shapes():
    params = model.init_tiny(CFG, seed=0)
    toks = np.arange(10, dtype=np.int32) % CFG["vocab"]
    logits = model.tiny_forward(params, toks, CFG)
    assert logits.shape == (10, CFG["vocab"])
    assert bool(np.isfinite(np.asarray(logits)).all())


def test_tiny_forward_is_causal():
    params = model.init_tiny(CFG, seed=1)
    t1 = np.array([1, 2, 3, 4, 5, 6], np.int32)
    t2 = np.array([1, 2, 3, 4, 17, 18], np.int32)
    l1 = np.asarray(model.tiny_forward(params, t1, CFG))
    l2 = np.asarray(model.tiny_forward(params, t2, CFG))
    np.testing.assert_allclose(l1[:4], l2[:4], rtol=1e-5, atol=1e-5)


def test_tiny_loss_decreases_with_one_adam_step():
    from compile.train_tiny import adam_init, adam_step
    import jax

    params = model.init_tiny(CFG, seed=2)
    rng = np.random.RandomState(0)
    batch = rng.randint(0, CFG["vocab"], size=(4, CFG["max_seq"])).astype(np.int32)
    grad_fn = jax.value_and_grad(lambda p, b: model.tiny_loss(p, b, CFG))
    loss0, grads = grad_fn(params, batch)
    opt = adam_init(params)
    # A few steps on the same batch must reduce its loss.
    for _ in range(5):
        loss, grads = grad_fn(params, batch)
        params, opt = adam_step(params, grads, opt, lr=1e-2)
    loss1, _ = grad_fn(params, batch)
    assert float(loss1) < float(loss0), f"{float(loss1)} !< {float(loss0)}"


def test_collect_qkv_shapes():
    params = model.init_tiny(CFG, seed=3)
    toks = np.arange(8, dtype=np.int32) % CFG["vocab"]
    _, qkvs = model.tiny_forward(params, toks, CFG, collect_qkv=True)
    assert len(qkvs) == CFG["n_layers"]
    for q, k, v in qkvs:
        assert q.shape == (8, CFG["d_model"])
        assert k.shape == v.shape == (8, CFG["d_model"])
