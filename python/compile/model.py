"""Layer-2 JAX model: the fused BESF/LATS attention pipeline and the tiny
transformer used for quality experiments.

The fused attention function is the compute graph that gets AOT-lowered to
HLO text (`compile.aot`) and executed from the Rust runtime on the request
path; it calls the Layer-1 Pallas kernels so everything lowers into a single
module.

Score arithmetic is float64 (exact for the 45-bit dynamic range the paper's
Scoreboard holds); jax_enable_x64 is switched on at import.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from .kernels import bitplane_qk, sparse_attn  # noqa: E402
from .kernels.ref import N_BITS, QMAX, QMIN  # noqa: E402


# ---------------------------------------------------------------------------
# Fused BESF attention (the AOT artifact body)
# ---------------------------------------------------------------------------

def quantize_sym_jnp(x):
    """In-graph symmetric INT12 PTQ: returns (integer values f32, scale)."""
    max_abs = jnp.max(jnp.abs(x))
    scale = jnp.where(max_abs > 0, max_abs / QMAX, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), QMIN, QMAX).astype(jnp.float32)
    return q, scale


def decompose_planes_jnp(k_int):
    """In-graph bit-plane decomposition: [seq, dim] ints → [12, seq, dim] {0,1}.

    Note: the shift vector is built as `11 - arange(12)` rather than a
    negative-step `arange` — the HLO-text interchange path (xla_extension
    0.5.1) mis-executes the `reverse` op that a negative-step iota lowers to.
    """
    k = jnp.asarray(k_int, jnp.int32) & 0xFFF
    shifts = (N_BITS - 1) - jnp.arange(N_BITS, dtype=jnp.int32)  # MSB first
    planes = (k[None, :, :] >> shifts[:, None, None]) & 1
    return planes.astype(jnp.float32)


def margins_jnp(q_int):
    """Per-round (min, max) margins, float64 — the Bit Margin Generator."""
    q = q_int.astype(jnp.float64)
    pos = jnp.sum(jnp.maximum(q, 0.0))
    neg = jnp.sum(jnp.minimum(q, 0.0))
    rem = jnp.array([2.0 ** (N_BITS - 1 - r) - 1.0 for r in range(N_BITS)],
                    jnp.float64)
    return rem * neg, rem * pos


def besf_mask(q_int, planes, alpha, radius_int, valid=None):
    """Survival mask from the 12-round BESF/LATS loop (statically unrolled).

    Args:
      q_int: [dim] float32 integer query.
      planes: [12, seq, dim] float32 bit planes.
      alpha, radius_int: LATS parameters (integer-score domain).
      valid: optional [seq] {0,1} — padding keys are never selected.

    Returns:
      (mask [seq] float32 {0,1}, exact_scores [seq] float64)
    """
    scores = bitplane_qk.cumulative_scores(q_int, planes)  # [12, seq] f64
    m_min, m_max = margins_jnp(q_int)
    seq = planes.shape[1]
    active = jnp.ones((seq,), bool)
    if valid is not None:
        active = active & (valid > 0)
    # Integer-domain band, rounded exactly like the Rust Lats (and the
    # hardware, whose threshold register is an integer).
    band = jnp.round(alpha * jnp.round(radius_int))
    neg_inf = jnp.float64(-jnp.inf)
    for r in range(N_BITS):
        lower = scores[r] + m_min[r]
        upper = scores[r] + m_max[r]
        eta = jnp.max(jnp.where(active, lower, neg_inf)) - band
        active = active & (upper >= eta)
    return active.astype(jnp.float32), scores[N_BITS - 1]


def besf_attention(q, k, v, alpha=0.6, radius_logit=5.0, valid=None):
    """The full BitStopper attention pipeline for one query (f32 in/out).

    Quantizes Q/K to INT12, decomposes K to bit planes, runs the fused
    BESF/LATS selection, and computes the masked softmax·V on the surviving
    tokens via the Layer-1 kernels.

    Returns (out [dim] f32, mask [seq] f32).
    """
    dim = q.shape[0]
    q_int, qs = quantize_sym_jnp(q)
    k_int, ks = quantize_sym_jnp(k)
    planes = decompose_planes_jnp(k_int)
    radius_int = jnp.maximum(
        jnp.round(
            radius_logit * jnp.sqrt(jnp.float64(dim))
            / (qs.astype(jnp.float64) * ks.astype(jnp.float64))
        ),
        1.0,
    )
    mask, exact = besf_mask(q_int, planes, alpha, radius_int, valid=valid)
    logit_scale = (qs * ks).astype(jnp.float64) / jnp.sqrt(jnp.float64(dim))
    logits = (exact * logit_scale).astype(jnp.float32)
    out = sparse_attn.masked_attention(logits, mask, v)
    return out, mask


def dense_attention(q, k, v, valid=None):
    """INT12 dense attention (the accuracy baseline), one query."""
    dim = q.shape[0]
    q_int, qs = quantize_sym_jnp(q)
    k_int, ks = quantize_sym_jnp(k)
    logits = (k_int.astype(jnp.float64) @ q_int.astype(jnp.float64))
    logits = logits * (qs * ks).astype(jnp.float64) / jnp.sqrt(jnp.float64(dim))
    mask = jnp.ones((k.shape[0],), jnp.float32) if valid is None else valid
    return sparse_attn.masked_attention(logits.astype(jnp.float32), mask, v), mask


# ---------------------------------------------------------------------------
# Tiny transformer (pre-LN GPT) — must match rust/src/model exactly
# ---------------------------------------------------------------------------

def init_tiny(cfg, seed=0):
    """Initialize parameters. cfg: dict(vocab, d_model, n_layers, n_heads, max_seq)."""
    rng = np.random.RandomState(seed)
    d = cfg["d_model"]

    def normal(*shape, scale):
        return jnp.asarray(rng.normal(0, scale, size=shape), jnp.float32)

    params = {
        "tok_emb": normal(cfg["vocab"], d, scale=0.08),
        "pos_emb": normal(cfg["max_seq"], d, scale=0.04),
        "ln_f.g": jnp.ones((d,), jnp.float32),
        "ln_f.b": jnp.zeros((d,), jnp.float32),
        "lm_head": normal(d, cfg["vocab"], scale=0.08),
    }
    proj = 0.08 / np.sqrt(2.0 * cfg["n_layers"])
    for i in range(cfg["n_layers"]):
        p = f"layers.{i}."
        params[p + "ln1.g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln1.b"] = jnp.zeros((d,), jnp.float32)
        params[p + "wq"] = normal(d, d, scale=0.08)
        params[p + "wk"] = normal(d, d, scale=0.08)
        params[p + "wv"] = normal(d, d, scale=0.08)
        params[p + "wo"] = normal(d, d, scale=proj)
        params[p + "ln2.g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln2.b"] = jnp.zeros((d,), jnp.float32)
        params[p + "w1"] = normal(d, 4 * d, scale=0.08)
        params[p + "b1"] = jnp.zeros((4 * d,), jnp.float32)
        params[p + "w2"] = normal(4 * d, d, scale=proj)
        params[p + "b2"] = jnp.zeros((d,), jnp.float32)
    return params


def _layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x ** 3)))


def tiny_forward(params, tokens, cfg, collect_qkv=False):
    """Forward pass: tokens [S] int32 → logits [S, vocab].

    With collect_qkv=True also returns per-layer (q, k, v) tensors
    [S, d_model] (pre-head-split) for trace export.
    """
    d = cfg["d_model"]
    heads = cfg["n_heads"]
    hd = d // heads
    s = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:s]
    qkvs = []
    causal = jnp.tril(jnp.ones((s, s), bool))
    for i in range(cfg["n_layers"]):
        p = f"layers.{i}."
        h = _layer_norm(x, params[p + "ln1.g"], params[p + "ln1.b"])
        q = h @ params[p + "wq"]
        k = h @ params[p + "wk"]
        v = h @ params[p + "wv"]
        if collect_qkv:
            qkvs.append((q, k, v))
        qh = q.reshape(s, heads, hd).transpose(1, 0, 2)
        kh = k.reshape(s, heads, hd).transpose(1, 0, 2)
        vh = v.reshape(s, heads, hd).transpose(1, 0, 2)
        att = jnp.einsum("hqd,hkd->hqk", qh, kh) / np.sqrt(hd)
        att = jnp.where(causal[None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("hqk,hkd->hqd", att, vh).transpose(1, 0, 2).reshape(s, d)
        x = x + out @ params[p + "wo"]
        h2 = _layer_norm(x, params[p + "ln2.g"], params[p + "ln2.b"])
        h2 = _gelu(h2 @ params[p + "w1"] + params[p + "b1"])
        x = x + h2 @ params[p + "w2"] + params[p + "b2"]
    x = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    logits = x @ params["lm_head"]
    if collect_qkv:
        return logits, qkvs
    return logits


def tiny_loss(params, tokens, cfg):
    """Mean next-token cross-entropy over a [B, S] batch."""
    def one(seq):
        logits = tiny_forward(params, seq, cfg)
        logp = jax.nn.log_softmax(logits[:-1], axis=-1)
        tgt = seq[1:]
        return -jnp.mean(jnp.take_along_axis(logp, tgt[:, None], axis=-1))

    return jnp.mean(jax.vmap(one)(tokens))
