"""Train the tiny character-level transformer and export build-time artifacts.

Substitute for the paper's OPT-1.3B / Llama2-7B + Wikitext-2 / Dolly quality
evaluation (see DESIGN.md §2): a real LM trained on a synthetic structured
corpus, whose real attention distributions and perplexity drive the
PPL-vs-α experiments (Fig. 10 PPL column, Fig. 13 (a)).

Outputs (into --out-dir, default ../artifacts/tiny_model):
  weights.bin      — BSWGHT01 format (rust/src/model/loader.rs)
  val_tokens.bin   — BSTOK001 held-out token stream
  traces.bin       — BSTRACE1 attention records captured from a forward pass
  golden_besf.txt  — BESF selection test vectors for the Rust golden test
  meta.txt         — training log / corpus stats

Usage: python -m compile.train_tiny --out-dir ../artifacts/tiny_model
"""

import argparse
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .kernels import ref

CFG = {"vocab": 0, "d_model": 64, "n_layers": 3, "n_heads": 4, "max_seq": 96}


# ---------------------------------------------------------------------------
# Synthetic structured corpus: a deterministic Markov grammar over words.
# Structured enough that attention matters (agreement between distant words),
# small enough to train in seconds.
# ---------------------------------------------------------------------------

SUBJECTS = ["the cat", "a dog", "the robot", "my friend", "the old sailor",
            "a tiny bird", "the compiler", "that engine"]
VERBS = ["runs", "jumps", "sleeps", "computes", "sails", "sings", "parses",
         "stalls"]
OBJECTS = ["over the hill", "in the garden", "through the night",
           "across the sea", "under the table", "beyond the wall",
           "with great care", "without a sound"]
CONNECT = ["and then", "but soon", "because", "while", "so"]


def make_corpus(n_sentences, seed):
    rng = np.random.RandomState(seed)
    parts = []
    for _ in range(n_sentences):
        s = rng.randint(len(SUBJECTS))
        # verb correlates with subject (long-range structure for attention)
        v = (s + rng.randint(2)) % len(VERBS)
        o = rng.randint(len(OBJECTS))
        sent = f"{SUBJECTS[s]} {VERBS[v]} {OBJECTS[o]}"
        if rng.rand() < 0.5:
            c = CONNECT[rng.randint(len(CONNECT))]
            s2 = rng.randint(len(SUBJECTS))
            sent += f" {c} {SUBJECTS[s2]} {VERBS[(s2 + rng.randint(2)) % len(VERBS)]}"
        parts.append(sent + ". ")
    return "".join(parts)


def tokenize(text):
    chars = sorted(set(text))
    stoi = {c: i for i, c in enumerate(chars)}
    return np.array([stoi[c] for c in text], np.uint16), chars


# ---------------------------------------------------------------------------
# Adam (inline — no optax dependency requirements)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_step(params, grads, state, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1 ** t) for k in params}
    vhat = {k: v[k] / (1 - b2 ** t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Binary writers (formats shared with rust/src/model/loader.rs, workload/trace.rs)
# ---------------------------------------------------------------------------

def write_weights(path, cfg, params):
    with open(path, "wb") as f:
        f.write(b"BSWGHT01")
        for key in ["vocab", "d_model", "n_layers", "n_heads", "max_seq"]:
            f.write(struct.pack("<I", cfg[key]))
        names = list(params.keys())
        f.write(struct.pack("<I", len(names)))
        for name in names:
            data = np.asarray(params[name], np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", data.ndim))
            for d in data.shape:
                f.write(struct.pack("<I", d))
            f.write(data.tobytes())


def write_tokens(path, tokens):
    with open(path, "wb") as f:
        f.write(b"BSTOK001")
        f.write(struct.pack("<I", len(tokens)))
        f.write(np.asarray(tokens, np.uint16).tobytes())


def write_traces(path, records):
    with open(path, "wb") as f:
        f.write(b"BSTRACE1")
        f.write(struct.pack("<I", len(records)))
        for q, k, v in records:
            seq, dim = k.shape
            assert q.shape == (dim,) and v.shape == (seq, dim)
            f.write(struct.pack("<II", seq, dim))
            f.write(np.asarray(q, np.float32).tobytes())
            f.write(np.asarray(k, np.float32).tobytes())
            f.write(np.asarray(v, np.float32).tobytes())


def write_golden(path, cases):
    """BESF golden vectors: plain text the Rust golden test parses."""
    with open(path, "w") as f:
        f.write(f"{len(cases)}\n")
        for q_int, k_int, alpha, radius_int, death, survivors in cases:
            seq, dim = k_int.shape
            f.write(f"case {dim} {seq} {alpha} {int(radius_int)}\n")
            f.write(" ".join(str(int(x)) for x in q_int) + "\n")
            for j in range(seq):
                f.write(" ".join(str(int(x)) for x in k_int[j]) + "\n")
            f.write(" ".join(str(int(d)) for d in death) + "\n")
            f.write(" ".join(str(j) for j in np.nonzero(survivors)[0]) + "\n")


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/tiny_model")
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    text = make_corpus(6000, args.seed)
    tokens, chars = tokenize(text)
    cfg = dict(CFG)
    cfg["vocab"] = len(chars)
    split = int(len(tokens) * 0.9)
    train_toks, val_toks = tokens[:split], tokens[split:]
    print(f"corpus: {len(tokens)} tokens, vocab {cfg['vocab']}")

    params = model.init_tiny(cfg, seed=args.seed)
    opt = adam_init(params)
    win = cfg["max_seq"]
    rng = np.random.RandomState(args.seed + 1)

    loss_fn = jax.jit(
        lambda p, b: model.tiny_loss(p, b, cfg), static_argnames=()
    ) if False else jax.jit(lambda p, b: model.tiny_loss(p, b, cfg))
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: model.tiny_loss(p, b, cfg)))

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        starts = rng.randint(0, len(train_toks) - win - 1, size=args.batch)
        batch = np.stack([train_toks[s:s + win].astype(np.int32) for s in starts])
        loss, grads = grad_fn(params, jnp.asarray(batch))
        params, opt = adam_step(params, grads, opt)
        losses.append(float(loss))
        if step % 100 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")
    final_loss = float(np.mean(losses[-20:]))

    # --- exports ---
    write_weights(os.path.join(args.out_dir, "weights.bin"), cfg, params)
    write_tokens(os.path.join(args.out_dir, "val_tokens.bin"),
                 val_toks[: 4096])
    write_tokens(os.path.join(args.out_dir, "train_tokens.bin"),
                 train_toks[: 4096])

    # Attention traces: real QKV from a validation window, per layer, head 0
    # and head 1, decode-position query (the last row).
    window = val_toks[:win].astype(np.int32)
    _, qkvs = model.tiny_forward(params, jnp.asarray(window), cfg,
                                 collect_qkv=True)
    hd = cfg["d_model"] // cfg["n_heads"]
    records = []
    for (q, k, v) in qkvs:
        for h in range(2):
            sl = slice(h * hd, (h + 1) * hd)
            records.append((
                np.asarray(q[-1, sl], np.float32),
                np.asarray(k[:, sl], np.float32),
                np.asarray(v[:, sl], np.float32),
            ))
    write_traces(os.path.join(args.out_dir, "traces.bin"), records)

    # Golden BESF vectors: quantized real traces + adversarial random cases.
    golden = []
    g_rng = np.random.RandomState(99)
    for idx, (q, k, v) in enumerate(records[:3]):
        q_int, qs = ref.quantize_sym(q)
        k_int, ks = ref.quantize_sym(k)
        alpha = [0.2, 0.5, 0.8][idx % 3]
        radius_int = round(ref.radius_int_from_logit(5.0, q.shape[0], qs, ks))
        death, surv, _ = ref.ref_besf_select(q_int, k_int, alpha, radius_int)
        golden.append((q_int, k_int, alpha, radius_int, death, surv))
    for idx in range(3):
        dim, seq = 16, 32
        q_int = g_rng.randint(-2048, 2048, size=dim).astype(np.float32)
        k_int = g_rng.randint(-2048, 2048, size=(seq, dim)).astype(np.float32)
        alpha = [0.0, 0.4, 1.0][idx]
        radius_int = int(g_rng.randint(1, 500000))
        death, surv, _ = ref.ref_besf_select(q_int, k_int, alpha, radius_int)
        golden.append((q_int, k_int, alpha, radius_int, death, surv))
    write_golden(os.path.join(args.out_dir, "golden_besf.txt"), golden)

    with open(os.path.join(args.out_dir, "meta.txt"), "w") as f:
        f.write(f"vocab {cfg['vocab']}\nd_model {cfg['d_model']}\n"
                f"n_layers {cfg['n_layers']}\nn_heads {cfg['n_heads']}\n"
                f"max_seq {cfg['max_seq']}\nsteps {args.steps}\n"
                f"final_loss {final_loss:.4f}\n"
                f"train_tokens {len(train_toks)}\nval_tokens {len(val_toks)}\n"
                f"chars {''.join(chars)!r}\n")
    print(f"exports written to {args.out_dir} (final loss {final_loss:.3f})")


if __name__ == "__main__":
    main()
