"""AOT export: lower the Layer-2 attention pipelines to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids which the runtime's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts

Produces, per (seq, dim, alpha) variant:
  attn_dense_{S}x{D}.hlo.txt        — INT12 dense attention baseline
  attn_bitstopper_{S}x{D}_a{A}.hlo.txt — fused BESF/LATS sparse attention
and a `manifest.txt` describing every artifact (consumed by the Rust
runtime's ArtifactRegistry).

Interfaces (all little-endian f32, shapes static per artifact):
  dense:      (q[D], k[S,D], v[S,D], valid[S]) -> (out[D], mask[S])
  bitstopper: (q[D], k[S,D], v[S,D], valid[S]) -> (out[D], mask[S])
`valid` masks padding keys (decode at context < S pads K/V with zeros).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (seq, dim) variants: a demo shape and the tiny model's head shape.
DEFAULT_SHAPES = [(256, 64), (128, 32), (128, 16)]
DEFAULT_ALPHAS = [0.6, 0.4]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    `as_hlo_text(True)` = print_large_constants: the default printer elides
    big constant arrays as `{...}`, which the downstream text parser silently
    reads as zeros — the whole LATS threshold pipeline (plane-weight /
    margin / triangular-accumulation constants) would degenerate.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO text contains elided constants"
    return text


def lower_dense(seq, dim):
    def fn(q, k, v, valid):
        out, mask = model.dense_attention(q, k, v, valid=valid)
        return out, mask

    spec_q = jax.ShapeDtypeStruct((dim,), jnp.float32)
    spec_k = jax.ShapeDtypeStruct((seq, dim), jnp.float32)
    spec_v = jax.ShapeDtypeStruct((seq, dim), jnp.float32)
    spec_m = jax.ShapeDtypeStruct((seq,), jnp.float32)
    return jax.jit(fn).lower(spec_q, spec_k, spec_v, spec_m)


def lower_bitstopper(seq, dim, alpha):
    def fn(q, k, v, valid):
        out, mask = model.besf_attention(q, k, v, alpha=alpha, valid=valid)
        return out, mask

    spec_q = jax.ShapeDtypeStruct((dim,), jnp.float32)
    spec_k = jax.ShapeDtypeStruct((seq, dim), jnp.float32)
    spec_v = jax.ShapeDtypeStruct((seq, dim), jnp.float32)
    spec_m = jax.ShapeDtypeStruct((seq,), jnp.float32)
    return jax.jit(fn).lower(spec_q, spec_k, spec_v, spec_m)


def export(out_dir, shapes=DEFAULT_SHAPES, alphas=DEFAULT_ALPHAS):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for seq, dim in shapes:
        name = f"attn_dense_{seq}x{dim}.hlo.txt"
        text = to_hlo_text(lower_dense(seq, dim))
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"{name} kind=dense seq={seq} dim={dim} alpha=0")
        print(f"wrote {name} ({len(text)} chars)")
        for alpha in alphas:
            aname = f"attn_bitstopper_{seq}x{dim}_a{int(alpha * 100):02d}.hlo.txt"
            text = to_hlo_text(lower_bitstopper(seq, dim, alpha))
            with open(os.path.join(out_dir, aname), "w") as f:
                f.write(text)
            manifest.append(
                f"{aname} kind=bitstopper seq={seq} dim={dim} alpha={alpha}"
            )
            print(f"wrote {aname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="single small variant (CI smoke)")
    args = ap.parse_args()
    if args.quick:
        export(args.out_dir, shapes=[(64, 32)], alphas=[0.6])
    else:
        export(args.out_dir)


if __name__ == "__main__":
    main()
