"""Layer-1 Pallas kernel: masked sparse attention (the V-PU).

Given exact attention logits, a survival mask from the BESF/LATS selection,
and the Value matrix, computes ``softmax(logits | mask) @ V`` with pruned
tokens receiving exactly zero weight — the V-PU's weighted summation over the
surviving rows.

Tiling: one grid step per (query) with the full context resident; at the
evaluation shapes (seq ≤ 4k, dim ≤ 128, f32) a [seq, dim] V tile is ≤ 2 MB —
on a real TPU this would block over seq with an online-softmax accumulator;
for the CPU interpret path a single block keeps the kernel transparent.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_attn_kernel(s_ref, m_ref, v_ref, o_ref):
    s = s_ref[...]
    m = m_ref[...]
    neg = jnp.finfo(s.dtype).min
    masked = jnp.where(m > 0, s, neg)
    # Numerically stable masked softmax.
    mx = jnp.max(masked)
    e = jnp.where(m > 0, jnp.exp(masked - mx), 0.0)
    p = e / jnp.sum(e)
    o_ref[...] = p @ v_ref[...]


@jax.jit
def masked_attention(logits, mask, v):
    """``softmax(logits restricted to mask) @ v``.

    Args:
      logits: [seq] float32 attention logits (already scaled by 1/sqrt(d)).
      mask: [seq] float32 in {0,1}; 1 = token survives.
      v: [seq, dim] float32 Value matrix.

    Returns:
      [dim] float32 attention output.
    """
    seq, dim = v.shape
    assert logits.shape == (seq,)
    assert mask.shape == (seq,)
    return pl.pallas_call(
        _masked_attn_kernel,
        out_shape=jax.ShapeDtypeStruct((dim,), jnp.float32),
        interpret=True,
    )(logits.astype(jnp.float32), mask.astype(jnp.float32), v.astype(jnp.float32))
