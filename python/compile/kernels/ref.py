"""Pure-jnp/numpy correctness oracle for the Layer-1 kernels and the BESF pipeline.

Everything here is written for clarity, not speed: explicit loops over bit
rounds, float64 score arithmetic (exact for the 45-bit dynamic range), and
direct translations of the paper's equations. The Pallas kernels
(`bitplane_qk`, `sparse_attn`), the fused Layer-2 model (`compile.model`) and
the Rust functional models are all validated against these functions.
"""

import numpy as np

N_BITS = 12
QMAX = 2047
QMIN = -2048


# ---------------------------------------------------------------------------
# Quantization / decomposition
# ---------------------------------------------------------------------------

def quantize_sym(x):
    """Symmetric per-tensor INT12 PTQ. Returns (int values as float32, scale)."""
    x = np.asarray(x, np.float32)
    max_abs = float(np.max(np.abs(x))) if x.size else 0.0
    scale = max_abs / QMAX if max_abs > 0 else 1.0
    q = np.clip(np.round(x / scale), QMIN, QMAX).astype(np.float32)
    return q, np.float32(scale)


def decompose_planes(k_int):
    """2's-complement bit planes of an INT12 matrix, MSB (sign) first.

    Args:
      k_int: [seq, dim] float32/int holding integers in [-2048, 2047].

    Returns:
      [N_BITS, seq, dim] float32 in {0, 1}.
    """
    k = np.asarray(k_int).astype(np.int64) & 0xFFF
    planes = np.stack(
        [(k >> (N_BITS - 1 - r)) & 1 for r in range(N_BITS)], axis=0
    )
    return planes.astype(np.float32)


def plane_weights():
    w = np.array([2.0 ** (N_BITS - 1 - r) for r in range(N_BITS)], np.float64)
    w[0] = -w[0]
    return w


# ---------------------------------------------------------------------------
# Reference kernels
# ---------------------------------------------------------------------------

def ref_bitplane_scores(q, planes):
    """Loop-and-sum reference of `bitplane_qk.bitplane_scores`."""
    n, seq, dim = planes.shape
    q = np.asarray(q, np.float64)
    out = np.zeros((n, seq), np.float64)
    for r in range(n):
        for j in range(seq):
            out[r, j] = float(np.dot(planes[r, j].astype(np.float64), q))
    return out.astype(np.float32)


def ref_cumulative_scores(q, planes):
    """Float64 cumulative weighted scores A^r (exact)."""
    partial = ref_bitplane_scores(q, planes).astype(np.float64)
    w = plane_weights()
    return np.cumsum(w[:, None] * partial, axis=0)


def ref_margins(q_int):
    """Per-round (min, max) uncertainty margins of a query (Eq. 4 / Fig. 6)."""
    q = np.asarray(q_int, np.float64)
    pos = float(np.sum(np.maximum(q, 0.0)))
    neg = float(np.sum(np.minimum(q, 0.0)))
    rem = np.array([2.0 ** (N_BITS - 1 - r) - 1.0 for r in range(N_BITS)])
    return rem * neg, rem * pos


def ref_besf_select(q_int, k_int, alpha, radius_int):
    """Reference BESF + LATS selection (paper §III-A/B).

    Returns (death_round [seq] int, survivors mask [seq] bool, exact scores).
    death_round = N_BITS means the token survived all rounds.
    """
    planes = decompose_planes(k_int)
    scores = ref_cumulative_scores(q_int, planes)  # [N_BITS, seq]
    m_min, m_max = ref_margins(q_int)
    seq = planes.shape[1]
    # Integer band, matching the Rust Lats and the hardware threshold register.
    band = np.round(alpha * np.round(max(radius_int, 1)))
    death = np.full(seq, N_BITS, np.int32)
    active = np.ones(seq, bool)
    for r in range(N_BITS):
        lower = scores[r] + m_min[r]
        upper = scores[r] + m_max[r]
        eta = np.max(lower[active]) - band
        dies = active & ~(upper >= eta)
        death[dies] = r
        active &= ~dies
        if not active.any():
            break
    exact = scores[N_BITS - 1]
    return death, active, exact


def ref_brute_force_select(q_int, k_int, alpha, radius_int):
    """Keep tokens within alpha*radius of the exact max — BESF must match."""
    q = np.asarray(q_int, np.float64)
    k = np.asarray(k_int, np.float64)
    exact = k @ q
    eta = np.max(exact) - np.round(alpha * np.round(max(radius_int, 1)))
    return exact >= eta


def ref_masked_attention(logits, mask, v):
    """Masked softmax @ V reference."""
    logits = np.asarray(logits, np.float64)
    mask = np.asarray(mask) > 0
    v = np.asarray(v, np.float64)
    masked = np.where(mask, logits, -np.inf)
    mx = np.max(masked)
    e = np.where(mask, np.exp(masked - mx), 0.0)
    p = e / np.sum(e)
    return (p @ v).astype(np.float32)


def ref_dense_attention(q, k, v):
    """Plain attention for one query (no quantization)."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    logits = k @ q / np.sqrt(q.shape[0])
    logits -= logits.max()
    p = np.exp(logits)
    p /= p.sum()
    return (p @ v).astype(np.float32)


def ref_int12_attention(qf, kf, vf):
    """INT12-quantized attention (the paper's accuracy baseline)."""
    qi, qs = quantize_sym(qf)
    ki, ks = quantize_sym(kf)
    vi, vs = quantize_sym(vf)
    dim = qi.shape[0]
    logits = np.asarray(ki, np.float64) @ np.asarray(qi, np.float64)
    logits *= float(qs) * float(ks) / np.sqrt(dim)
    logits -= logits.max()
    p = np.exp(logits)
    p /= p.sum()
    return (p @ (np.asarray(vi, np.float64) * float(vs))).astype(np.float32)


def radius_int_from_logit(radius_logit, dim, q_scale, k_scale):
    """Convert the paper's logit-domain radius (default 5) to integer scores."""
    return float(radius_logit) * np.sqrt(dim) / (float(q_scale) * float(k_scale))
