"""Layer-1 Pallas kernel: bit-plane partial dot products (the BRAT hot loop).

The paper's PE lane computes, per cycle, the dot product of a 12-bit query
with a 1-bit Key plane (64 dims). On TPU there is no 1-bit datapath, so we
map the insight onto the MXU (see DESIGN.md §Hardware-Adaptation): each bit
plane is a {0,1} matrix and the per-round partial scores for *all* keys are
one `planes[r] @ q` matrix-vector product — a dense MXU-shaped op over
bit-plane operands. The 12 planes stream through the same VMEM tile buffers
(BlockSpec over the plane axis), the analogue of the paper's on-demand
bit-plane fetch; early-terminated work is expressed as masking at Layer 2 and
accounted analytically.

interpret=True throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical.
"""

import functools

import jax

# Score accumulation is float64: integer scores reach ~2^45 (the paper's
# 45-bit Scoreboard), beyond f32's exact range.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.experimental import pallas as pl  # noqa: E402

N_BITS = 12


def plane_weights(dtype=jnp.float32):
    """Signed weight of each bit-plane round (round 0 = sign plane)."""
    w = [2.0 ** (N_BITS - 1 - r) for r in range(N_BITS)]
    w[0] = -w[0]
    return jnp.array(w, dtype)


def _scores_kernel(q_ref, planes_ref, out_ref):
    # planes_ref: [N_BITS, seq, dim]; q_ref: [dim]; out_ref: [N_BITS, seq].
    # One matrix-vector product per plane — each is MXU-shaped; on TPU the
    # plane axis would become a BlockSpec grid streaming planes through the
    # same VMEM tiles (the analogue of on-demand bit-plane fetch). The CPU
    # interchange path (xla_extension 0.5.1) cannot execute the while-loop
    # HLO that a gridded interpret-mode pallas_call lowers to, so the kernel
    # is single-block here; the grid decomposition is documented in
    # DESIGN.md §Hardware-Adaptation.
    out_ref[...] = jnp.einsum("rsd,d->rs", planes_ref[...], q_ref[...])


@functools.partial(jax.jit, static_argnames=("block_seq",))
def bitplane_scores(q, planes, block_seq=128):
    """Unweighted per-plane dot products.

    Args:
      q: [dim] float32 holding INT12 integer values.
      planes: [N_BITS, seq, dim] float32 in {0, 1}.
      block_seq: accepted for API stability (TPU tiling parameter); the CPU
        interpret path runs single-block (see `_scores_kernel`).

    Returns:
      [N_BITS, seq] float32: ``out[r, j] = sum_d q[d] * planes[r, j, d]``.
    """
    n, seq, dim = planes.shape
    assert n == N_BITS, f"expected {N_BITS} planes, got {n}"
    _ = (block_seq, dim)
    return pl.pallas_call(
        _scores_kernel,
        out_shape=jax.ShapeDtypeStruct((n, seq), jnp.float32),
        interpret=True,
    )(q, planes)


def _weighted_cumulative(partials, dtype=jnp.float64):
    """Cumulative weighted partial scores A^r = sum_{t<=r} w_t * partial_t.

    Accumulates in float64: integer scores reach ~2^45 (the paper's 45-bit
    Scoreboard), beyond f32's exact range. On a real TPU this accumulation
    would live in the MXU's s32 accumulators; on CPU-PJRT f64 is exact.

    Implemented as a lower-triangular matmul rather than `jnp.cumsum`: the
    prefix-sum HLO that cumsum lowers to mis-executes on the HLO-text
    interchange path (xla_extension 0.5.1), and a [12×12] triangular matmul
    is the MXU-native formulation anyway.
    """
    w = plane_weights(dtype)
    weighted = w[:, None] * partials.astype(dtype)
    lower_tri = jnp.tril(jnp.ones((N_BITS, N_BITS), dtype))
    return lower_tri @ weighted


def cumulative_scores(q, planes, block_seq=128):
    """[N_BITS, seq] float64 cumulative scores after each round."""
    return _weighted_cumulative(bitplane_scores(q, planes, block_seq=block_seq))
