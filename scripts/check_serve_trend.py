#!/usr/bin/env python3
"""Compare fresh bench JSON against the committed baselines.

Usage:
    scripts/check_serve_trend.py [--refresh] [SERVE] [SERVE_BASELINE] [HOTPATH] [HOTPATH_BASELINE] [LOAD] [LOAD_BASELINE]

SERVE            defaults to BENCH_serve.json          (written by
                                                        `cargo bench --bench hotpath`)
SERVE_BASELINE   defaults to BENCH_serve.baseline.json (committed)
HOTPATH          defaults to BENCH_hotpath.json        (same bench run)
HOTPATH_BASELINE defaults to BENCH_hotpath.baseline.json (committed)
LOAD             defaults to BENCH_load.json           (written by
                                                        `bitstopper loadgen`)
LOAD_BASELINE    defaults to BENCH_load.baseline.json  (committed)

`--refresh` rewrites each baseline from the corresponding current JSON
(dropping any hand-seeded `"seeded": true` flag and its note) instead of
checking — the deliberate replace-the-bound step, meant for the same PR
that moves the numbers.

Policy (ROADMAP "BENCH trend tracking in CI"):

* Every `serve_decode_b*` / `serve_spec_q*` / `serve_scored_*` /
  `serve_spill_*` cost row is compared by p50 (more robust than the mean on
  shared CI machines — see EXPERIMENTS.md §Perf). A row more than
  REGRESSION_PCT slower than its baseline fails the check. The spill rows
  cover the disk tier: serialize/deserialize cost of the ModelContext wire
  format, cold-step promote latency vs context length, and the hot:cold
  session-mix decode cost (DESIGN.md §14).
* Every `load_*` SLO row is compared by **p99** — SLOs are written against
  the tail, and the loadgen histograms are log-bucketed, so the tail is the
  stable, meaningful number. A p99 more than REGRESSION_PCT above its
  baseline fails the check (DESIGN.md §15).
* Every derived ratio whose name contains "speedup" — in BOTH files — is a
  machine-independent higher-is-better number (kernel A vs kernel B on the
  same box). One dropping below RATIO_FLOOR × baseline fails the check.
  Other derived keys (thread counts, growth factors, parity rows) are
  informational only.
* Keys present in only one file are reported but do not fail (bench suites
  may grow; baselines may be seeded sparsely).
* A missing baseline file passes with an instruction to commit one; a
  missing hotpath current file passes with a note (serve-only runs).
* A baseline carrying `"seeded": true` was hand-written as a conservative
  bound rather than captured from a run — the check still gates, but the
  note below reminds you to replace it with measured numbers.

Refresh a baseline deliberately, in the same PR that is *supposed* to move
the numbers:  scripts/check_serve_trend.py --refresh  (strips the
`"seeded"` flag for you).

Exit codes: 0 ok / baseline missing / refreshed, 1 regression,
2 malformed input.
"""

import json
import sys
from pathlib import Path

REGRESSION_PCT = 10.0  # serve rows: fail if > +10% slower
RATIO_FLOOR = 0.90     # speedup ratios: fail if < 90% of baseline


def load_doc(path: Path):
    return json.loads(path.read_text())


SERVE_ROW_PREFIXES = ("serve_decode_", "serve_spec_", "serve_scored_", "serve_spill_")


def serve_rows(doc):
    rows = {}
    for row in doc.get("rows", []):
        name = row.get("name", "")
        if name.startswith(SERVE_ROW_PREFIXES):
            rows[name] = float(row.get("p50", row.get("mean", "nan")))
    return rows


def load_slo_rows(doc):
    """`load_*` rows keyed by p99 — the number SLOs are written against."""
    rows = {}
    for row in doc.get("rows", []):
        name = row.get("name", "")
        if name.startswith("load_"):
            rows[name] = float(row.get("p99", row.get("p95", "nan")))
    return rows


def speedup_ratios(doc):
    return {
        name: float(v)
        for name, v in doc.get("derived", {}).items()
        if "speedup" in name
    }


def note_if_seeded(doc, path):
    if doc.get("seeded"):
        print(f"note: {path} is a hand-seeded conservative bound, not a "
              "measured run;")
        print(f"      replace it with real numbers when a toolchain run is "
              f"available: scripts/check_serve_trend.py --refresh")


def check_serve_rows(current, baseline, failures):
    print(f"serve cost/token trend (p50, fail threshold: +{REGRESSION_PCT:.0f}%)")
    for name in sorted(set(current) | set(baseline)):
        if name not in current:
            print(f"  {name:<28} missing from current run (row removed?)")
            continue
        if name not in baseline:
            print(f"  {name:<28} {current[name]:9.3f} ms/token  (new row, no baseline)")
            continue
        base, cur = baseline[name], current[name]
        delta_pct = 100.0 * (cur - base) / base if base > 0 else float("inf")
        verdict = "ok"
        if delta_pct > REGRESSION_PCT:
            verdict = "REGRESSION"
            failures.append(name)
        print(f"  {name:<28} {base:9.3f} -> {cur:9.3f} ms/token "
              f"({delta_pct:+6.1f}%)  {verdict}")


def check_load_rows(current, baseline, failures):
    print(f"load SLO trend (p99, fail threshold: +{REGRESSION_PCT:.0f}%)")
    for name in sorted(set(current) | set(baseline)):
        if name not in current:
            print(f"  {name:<28} missing from current run (row removed?)")
            continue
        if name not in baseline:
            print(f"  {name:<28} {current[name]:12.1f} us p99  (new row, no baseline)")
            continue
        base, cur = baseline[name], current[name]
        delta_pct = 100.0 * (cur - base) / base if base > 0 else float("inf")
        verdict = "ok"
        if delta_pct > REGRESSION_PCT:
            verdict = "REGRESSION"
            failures.append(name)
        print(f"  {name:<28} {base:12.1f} -> {cur:12.1f} us p99 "
              f"({delta_pct:+6.1f}%)  {verdict}")


def check_ratios(label, current, baseline, failures):
    print(f"{label} speedup-ratio trend (higher is better, "
          f"fail floor: {RATIO_FLOOR:.2f}x baseline)")
    for name in sorted(set(current) | set(baseline)):
        if name not in current:
            print(f"  {name:<32} missing from current run (ratio removed?)")
            continue
        if name not in baseline:
            print(f"  {name:<32} {current[name]:8.3f}x  (new ratio, no baseline)")
            continue
        base, cur = baseline[name], current[name]
        verdict = "ok"
        if base > 0 and cur < base * RATIO_FLOOR:
            verdict = "REGRESSION"
            failures.append(name)
        print(f"  {name:<32} {base:8.3f}x -> {cur:8.3f}x  {verdict}")


def refresh_baseline(src: Path, dst: Path):
    """Rewrite `dst` from the measured `src`, dropping any seeded marker."""
    doc = load_doc(src)
    was_seeded = doc.pop("seeded", None)
    doc.pop("note", None)  # the note explains the seeding; stale without it
    dst.write_text(json.dumps(doc, indent=2) + "\n")
    origin = " (was hand-seeded)" if was_seeded else ""
    print(f"refreshed {dst} from {src}{origin}")


def main(argv):
    argv = list(argv)
    do_refresh = "--refresh" in argv
    if do_refresh:
        argv.remove("--refresh")
    serve_cur = Path(argv[1] if len(argv) > 1 else "BENCH_serve.json")
    serve_base = Path(argv[2] if len(argv) > 2 else "BENCH_serve.baseline.json")
    hot_cur = Path(argv[3] if len(argv) > 3 else "BENCH_hotpath.json")
    hot_base = Path(argv[4] if len(argv) > 4 else "BENCH_hotpath.baseline.json")
    load_cur = Path(argv[5] if len(argv) > 5 else "BENCH_load.json")
    load_base = Path(argv[6] if len(argv) > 6 else "BENCH_load.baseline.json")

    if do_refresh:
        if not serve_cur.exists():
            print(f"error: {serve_cur} not found — run "
                  "`cargo bench --bench hotpath` first")
            return 2
        try:
            refresh_baseline(serve_cur, serve_base)
            if hot_cur.exists():
                refresh_baseline(hot_cur, hot_base)
            else:
                print(f"note: {hot_cur} not found; hotpath baseline untouched.")
            if load_cur.exists():
                refresh_baseline(load_cur, load_base)
            else:
                print(f"note: {load_cur} not found; load baseline untouched "
                      "(run `bitstopper loadgen` to produce one).")
        except (json.JSONDecodeError, ValueError) as e:
            print(f"error: malformed bench json: {e}")
            return 2
        return 0

    if not serve_cur.exists():
        print(f"error: {serve_cur} not found — run "
              "`cargo bench --bench hotpath` first")
        return 2

    failures = []
    try:
        cur_doc = load_doc(serve_cur)
        if not serve_rows(cur_doc):
            print(f"error: {serve_cur} has no serve_* rows")
            return 2
        if serve_base.exists():
            base_doc = load_doc(serve_base)
            note_if_seeded(base_doc, serve_base)
            check_serve_rows(serve_rows(cur_doc), serve_rows(base_doc), failures)
            print()
            check_ratios("serve", speedup_ratios(cur_doc),
                         speedup_ratios(base_doc), failures)
        else:
            print(f"note: no committed baseline at {serve_base}; passing.")
            print(f"      seed the trend with: cp {serve_cur} {serve_base}")

        print()
        if not hot_cur.exists():
            print(f"note: {hot_cur} not found (serve-only run?); "
                  "skipping hotpath trend.")
        elif not hot_base.exists():
            print(f"note: no committed baseline at {hot_base}; passing.")
            print(f"      seed the trend with: cp {hot_cur} {hot_base}")
        else:
            hot_cur_doc = load_doc(hot_cur)
            hot_base_doc = load_doc(hot_base)
            note_if_seeded(hot_base_doc, hot_base)
            check_ratios("hotpath", speedup_ratios(hot_cur_doc),
                         speedup_ratios(hot_base_doc), failures)

        print()
        if not load_cur.exists():
            print(f"note: {load_cur} not found (no loadgen run?); "
                  "skipping load SLO trend.")
        elif not load_base.exists():
            print(f"note: no committed baseline at {load_base}; passing.")
            print(f"      seed the trend with: cp {load_cur} {load_base}")
        else:
            load_cur_doc = load_doc(load_cur)
            load_base_doc = load_doc(load_base)
            note_if_seeded(load_base_doc, load_base)
            if not load_slo_rows(load_cur_doc):
                print(f"error: {load_cur} has no load_* rows")
                return 2
            check_load_rows(load_slo_rows(load_cur_doc),
                            load_slo_rows(load_base_doc), failures)
            print()
            check_ratios("load", speedup_ratios(load_cur_doc),
                         speedup_ratios(load_base_doc), failures)
    except (json.JSONDecodeError, ValueError) as e:
        print(f"error: malformed bench json: {e}")
        return 2

    if failures:
        print(f"\nFAIL: {len(failures)} metric(s) regressed vs the committed "
              "baseline(s):")
        for name in failures:
            print(f"  - {name}")
        print("If the change is intentional, refresh the baseline(s) in the "
              "same PR:\n"
              f"    cp {serve_cur} {serve_base}\n"
              f"    cp {hot_cur} {hot_base}\n"
              f"    cp {load_cur} {load_base}")
        return 1
    print("\nOK: no serve, kernel-speedup, or load-SLO regression.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
