#!/usr/bin/env python3
"""Compare a fresh BENCH_serve.json against the committed baseline.

Usage:
    scripts/check_serve_trend.py [CURRENT] [BASELINE]

CURRENT  defaults to BENCH_serve.json        (written by `cargo bench --bench
                                              hotpath -- --serve-only`)
BASELINE defaults to BENCH_serve.baseline.json (committed; refresh it
                                              deliberately when a PR is
                                              *supposed* to change serving
                                              cost)

Policy (ROADMAP "BENCH_serve.json trend tracking in CI"):

* Every `serve_decode_b*` cost/token row is compared by p50 (more robust
  than the mean on shared CI machines — see EXPERIMENTS.md §Perf).
* A row more than REGRESSION_PCT slower than the baseline fails the check.
* Rows present in only one file are reported but do not fail (bench suites
  may grow).
* A missing baseline passes with an instruction to commit one: the first
  toolchain run seeds the trend.

Exit codes: 0 ok / baseline missing, 1 regression, 2 malformed input.
"""

import json
import sys
from pathlib import Path

REGRESSION_PCT = 10.0


def load_rows(path: Path):
    doc = json.loads(path.read_text())
    rows = {}
    for row in doc.get("rows", []):
        name = row.get("name", "")
        if name.startswith("serve_decode_"):
            rows[name] = float(row.get("p50", row.get("mean", "nan")))
    return rows


def main(argv):
    current_path = Path(argv[1] if len(argv) > 1 else "BENCH_serve.json")
    baseline_path = Path(argv[2] if len(argv) > 2 else "BENCH_serve.baseline.json")

    if not current_path.exists():
        print(f"error: {current_path} not found — run "
              "`cargo bench --bench hotpath -- --serve-only` first")
        return 2
    if not baseline_path.exists():
        print(f"note: no committed baseline at {baseline_path}; passing.")
        print(f"      seed the trend with: cp {current_path} {baseline_path}")
        return 0

    try:
        current = load_rows(current_path)
        baseline = load_rows(baseline_path)
    except (json.JSONDecodeError, ValueError) as e:
        print(f"error: malformed bench json: {e}")
        return 2
    if not current:
        print(f"error: {current_path} has no serve_decode_* rows")
        return 2

    failures = []
    print(f"serve cost/token trend vs {baseline_path} "
          f"(fail threshold: +{REGRESSION_PCT:.0f}%)")
    for name in sorted(set(current) | set(baseline)):
        if name not in current:
            print(f"  {name:<24} missing from current run (row removed?)")
            continue
        if name not in baseline:
            print(f"  {name:<24} {current[name]:9.3f} ms/token  (new row, no baseline)")
            continue
        base, cur = baseline[name], current[name]
        delta_pct = 100.0 * (cur - base) / base if base > 0 else float("inf")
        verdict = "ok"
        if delta_pct > REGRESSION_PCT:
            verdict = "REGRESSION"
            failures.append((name, base, cur, delta_pct))
        print(f"  {name:<24} {base:9.3f} -> {cur:9.3f} ms/token "
              f"({delta_pct:+6.1f}%)  {verdict}")

    if failures:
        print(f"\nFAIL: {len(failures)} row(s) regressed more than "
              f"{REGRESSION_PCT:.0f}% vs the committed baseline.")
        print("If the slowdown is intentional, refresh the baseline in the "
              "same PR:\n"
              f"    cp {current_path} {baseline_path}")
        return 1
    print("\nOK: no serve cost/token regression.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
